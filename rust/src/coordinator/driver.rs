//! The job driver ("jobtracker"): plan → schedule → execute → merge.
//!
//! One call to [`run_job`] is one MapReduce job of the paper: a feature
//! extraction pass of one algorithm over one HIB bundle.
//! [`run_fused_job`] generalizes it to the paper's actual experiment —
//! *several* algorithms in a single pass: the bundle is read, decoded,
//! tiled and gray-converted once, shared detector intermediates are
//! computed once per tile ([`crate::features::fused`]), and one census
//! per algorithm comes out.  `run_job` is the single-algorithm case of
//! the same engine.
//!
//! Real compute (tile executions) runs on real worker threads (one per
//! map slot, `nodes × slots_per_node` total); disk/network time is
//! *modeled* by [`crate::cluster::CostModel`] and accumulated per slot.
//! The reported job time is
//!
//! ```text
//! sim_seconds = job_startup + max_over_slots( Σ task_overhead
//!                                            + modeled_io + measured_compute )
//! ```
//!
//! which is the quantity comparable to the paper's Table 1 cells (see
//! README §Reproducing the paper's tables for the measured-vs-modeled
//! breakdown of every column).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::cluster::CostModel;
use crate::config::Config;
use crate::dfs::{Dfs, NodeId};
use crate::features::matching::{match_descriptors_while, ransac_translation};
use crate::features::nms::rank_truncate;
use crate::features::{self, Algorithm, Descriptors, GrayImage};
use crate::hib::{self, BundleReader, RecordMeta};
use crate::imagery::tiler::{extract_tile_f32, TileIter};
use crate::imagery::Rgba8Image;
use crate::metrics::Registry;
use crate::runtime::TileFeatures;
use crate::util::{DifetError, Result, Stopwatch};

use super::job::{
    mapper_retention, pair_seed, CanvasTile, FusedJobSpec, ImageCensus, JobReport, JobSpec,
    MapOutput, MosaicReport, MosaicSpec, PairResult, PairTask, RegistrationReport,
    RegistrationSpec,
};
use super::scheduler::{Assignment, Scheduler, TaskDescriptor, TaskHandle};
use super::shuffle;

/// Anything that can extract features from one tile: the PJRT engine in
/// production, the pure-Rust baseline as hermetic fallback.
pub trait TileExecutor: Sync {
    fn run_tile(&self, alg: &str, tile: &[f32], core: [i32; 4]) -> Result<TileFeatures>;

    /// Run several algorithms over ONE tile, returning results in `algs`
    /// order.  The default loops [`TileExecutor::run_tile`];
    /// [`NativeExecutor`] overrides it with the fused
    /// shared-intermediate pass, which must stay byte-identical to the
    /// loop (asserted by `rust/tests/fused_parity.rs`).
    fn run_tile_multi(
        &self,
        algs: &[&str],
        tile: &[f32],
        core: [i32; 4],
    ) -> Result<Vec<TileFeatures>> {
        algs.iter().map(|a| self.run_tile(a, tile, core)).collect()
    }

    /// Executor label for reports ("pjrt" / "native").
    fn label(&self) -> &'static str;
}

impl TileExecutor for crate::runtime::Engine {
    fn run_tile(&self, alg: &str, tile: &[f32], core: [i32; 4]) -> Result<TileFeatures> {
        self.run(alg, tile, core)
    }
    fn label(&self) -> &'static str {
        "pjrt"
    }
}

/// Pure-Rust executor (`crate::features`), used when artifacts are absent
/// and as the sequential-baseline compute body.
pub struct NativeExecutor;

fn core_tuple(core: [i32; 4]) -> (usize, usize, usize, usize) {
    (
        core[0].max(0) as usize,
        core[1].max(0) as usize,
        core[2].max(0) as usize,
        core[3].max(0) as usize,
    )
}

impl TileExecutor for NativeExecutor {
    fn run_tile(&self, alg: &str, tile: &[f32], core: [i32; 4]) -> Result<TileFeatures> {
        let algorithm = Algorithm::parse(alg)?;
        let gray = GrayImage::from_tile_f32(tile, crate::TILE, crate::TILE);
        let cap = features::params::topk(alg);
        let ex = features::extract(algorithm, &gray, core_tuple(core), cap);
        Ok(TileFeatures {
            count: ex.count,
            keypoints: ex.keypoints,
            descriptors: ex.descriptors,
        })
    }

    /// Fused path: one grayscale conversion and one set of shared
    /// intermediates (structure tensor, FAST ring maps, σ=2 smoothing)
    /// feed every requested algorithm.
    fn run_tile_multi(
        &self,
        algs: &[&str],
        tile: &[f32],
        core: [i32; 4],
    ) -> Result<Vec<TileFeatures>> {
        let parsed = algs
            .iter()
            .map(|a| Algorithm::parse(a))
            .collect::<Result<Vec<Algorithm>>>()?;
        let caps: Vec<usize> = algs.iter().map(|a| features::params::topk(a)).collect();
        let gray = GrayImage::from_tile_f32(tile, crate::TILE, crate::TILE);
        let extractions = features::fused::extract_multi(&parsed, &gray, core_tuple(core), &caps);
        Ok(extractions
            .into_iter()
            .map(|ex| TileFeatures {
                count: ex.count,
                keypoints: ex.keypoints,
                descriptors: ex.descriptors,
            })
            .collect())
    }

    fn label(&self) -> &'static str {
        "native"
    }
}

/// Test hooks: deterministic failure injection.
#[derive(Default)]
pub struct JobHooks {
    /// `fail(task_id, attempt)` → should this attempt die?
    #[allow(clippy::type_complexity)]
    pub fail: Option<Box<dyn Fn(usize, usize) -> bool + Sync>>,
}

/// Run one extraction job on the simulated cluster.
pub fn run_job(
    cfg: &Config,
    dfs: &Dfs,
    executor: &dyn TileExecutor,
    spec: &JobSpec,
    registry: &Registry,
    hooks: &JobHooks,
) -> Result<JobReport> {
    let fused: FusedJobSpec = spec.into();
    let mut reports = run_fused_job(cfg, dfs, executor, &fused, registry, hooks)?;
    reports
        .pop()
        .ok_or_else(|| DifetError::Job("fused engine returned no report".into()))
}

/// One slot-completed work item: its payload plus the virtual-time
/// accounting every task contributes to the job clock.
struct SlotWork<R> {
    payload: R,
    /// Virtual time this task adds to its slot (overhead + io + compute).
    virtual_ns: u64,
    compute_ns: u64,
    io_ns: u64,
}

/// Aggregated slot accounting after a job drains.
struct SlotTotals {
    /// Max over slots of Σ virtual task time (the job-clock term).
    max_slot_ns: u64,
    compute_ns: u64,
    io_ns: u64,
}

/// The shared worker-slot engine: spawn `nodes × slots_per_node` threads,
/// drain `scheduler`, run `body` once per task attempt and `merge` once
/// per *winning* attempt.  Both job shapes — the map-shaped extraction
/// and the reduce-shaped registration — run on this skeleton, so retry,
/// cancellation, speculation-twin and virtual-time semantics cannot
/// diverge between them.
fn run_slots<D, R, B, M>(
    cluster: &crate::config::ClusterConfig,
    scheduler: &Scheduler<D>,
    body: B,
    merge: M,
) -> SlotTotals
where
    D: super::scheduler::WorkItem,
    B: Fn(&D, &TaskHandle, NodeId) -> Result<Option<SlotWork<R>>> + Sync,
    M: Fn(&D, R) + Sync,
{
    let compute_ns = AtomicU64::new(0);
    let io_ns = AtomicU64::new(0);
    let max_slot_ns = AtomicU64::new(0);
    std::thread::scope(|scope| {
        for node in 0..cluster.nodes {
            for _slot in 0..cluster.slots_per_node {
                let body = &body;
                let merge = &merge;
                let compute_ns = &compute_ns;
                let io_ns = &io_ns;
                let max_slot_ns = &max_slot_ns;
                scope.spawn(move || {
                    let mut slot_virtual_ns = 0u64;
                    loop {
                        match scheduler.next_assignment(NodeId(node)) {
                            Assignment::Done => break,
                            Assignment::Run(task, handle) => {
                                match body(&task, &handle, NodeId(node)) {
                                    Ok(Some(work)) => {
                                        slot_virtual_ns += work.virtual_ns;
                                        compute_ns.fetch_add(work.compute_ns, Ordering::Relaxed);
                                        io_ns.fetch_add(work.io_ns, Ordering::Relaxed);
                                        if scheduler.report_success(&handle) {
                                            merge(&task, work.payload);
                                        }
                                    }
                                    Ok(None) => scheduler.report_cancelled(&handle),
                                    Err(e) => scheduler.report_failure(&handle, &e.to_string()),
                                }
                            }
                        }
                    }
                    max_slot_ns.fetch_max(slot_virtual_ns, Ordering::Relaxed);
                });
            }
        }
    });
    SlotTotals {
        max_slot_ns: max_slot_ns.load(Ordering::Relaxed),
        compute_ns: compute_ns.load(Ordering::Relaxed),
        io_ns: io_ns.load(Ordering::Relaxed),
    }
}

/// Run ONE MapReduce pass that extracts every algorithm in `spec`,
/// sharing the split read, record decode, tiling and per-tile
/// intermediates across them.  Returns one [`JobReport`] per algorithm
/// (in `spec.algorithms` order); job-level quantities — `sim_seconds`,
/// `wall_seconds`, `compute_seconds`, `io_seconds`, `counters` — are
/// those of the shared pass and therefore identical across the reports.
pub fn run_fused_job(
    cfg: &Config,
    dfs: &Dfs,
    executor: &dyn TileExecutor,
    spec: &FusedJobSpec,
    registry: &Registry,
    hooks: &JobHooks,
) -> Result<Vec<JobReport>> {
    if spec.algorithms.is_empty() {
        return Ok(Vec::new());
    }
    if spec.algorithms.len() != spec.per_image_caps.len() {
        return Err(DifetError::Config(
            "fused job: one per-image cap per algorithm required".into(),
        ));
    }
    let n_algs = spec.algorithms.len();
    let wall = Stopwatch::start();
    let cost = CostModel::new(&cfg.cluster);

    // ---- plan: read the bundle index, compute record-aligned splits ----
    // (jobtracker-side planning; its I/O is part of the modeled startup.)
    let (bundle_bytes, _) = dfs.read_file(&spec.bundle_path, NodeId(0))?;
    let (tasks, metas) = {
        let reader = BundleReader::open(&bundle_bytes)?;
        let metas: Vec<RecordMeta> = reader.metas().to_vec();
        // HIPI semantics (paper §3): one mapper per image.  A 1-byte split
        // target makes every record its own split; block-sized splits are
        // the plain-Hadoop alternative (ablations A4 measures the trade).
        let split_target = if cfg.scheduler.split_per_image {
            1
        } else {
            cfg.storage.block_size as u64
        };
        let splits = hib::splits(&reader, split_target);
        let mut tasks = Vec::with_capacity(splits.len());
        for (i, s) in splits.iter().enumerate() {
            let preferred = dfs
                .locate_range(&spec.bundle_path, s.byte_start, s.byte_end)
                .unwrap_or_default();
            tasks.push(TaskDescriptor {
                task_id: i,
                first_record: s.first_record,
                last_record: s.last_record,
                byte_start: s.byte_start,
                byte_end: s.byte_end,
                preferred_nodes: preferred,
            });
        }
        (tasks, metas)
    };
    drop(bundle_bytes);
    let n_tasks = tasks.len();
    let n_images = metas.len();

    let scheduler = Scheduler::new(tasks, &cfg.scheduler);
    let outputs: Mutex<Vec<Vec<MapOutput>>> = Mutex::new(vec![Vec::new(); n_algs]);
    let tiles_counter = registry.counter("tiles_processed");
    let tile_hist = registry.histogram("tile_latency");

    let totals = run_slots(
        &cfg.cluster,
        &scheduler,
        |desc: &TaskDescriptor, handle, node| {
            map_task(
                cfg, dfs, executor, spec, hooks, &cost, &metas, desc, handle, node,
                &tiles_counter, &tile_hist,
            )
        },
        |_desc, task_outputs| {
            let mut merged = outputs.lock().unwrap();
            for (dst, src) in merged.iter_mut().zip(task_outputs) {
                dst.extend(src);
            }
        },
    );

    if let Some(reason) = scheduler.abort_reason() {
        return Err(DifetError::Job(reason));
    }

    let outputs = outputs.into_inner().unwrap();
    let sim_seconds = cost.job_startup() + totals.max_slot_ns as f64 * 1e-9;
    let wall_seconds = wall.elapsed_secs();
    let compute_seconds = totals.compute_ns as f64 * 1e-9;
    let io_seconds = totals.io_ns as f64 * 1e-9;

    let mut counters = std::collections::BTreeMap::new();
    counters.insert("tasks".into(), n_tasks as u64);
    counters.insert(
        "data_local_tasks".into(),
        scheduler.data_local_tasks.load(Ordering::Relaxed),
    );
    counters.insert(
        "rack_remote_tasks".into(),
        scheduler.rack_remote_tasks.load(Ordering::Relaxed),
    );
    counters.insert(
        "speculative_launches".into(),
        scheduler.speculative_launches.load(Ordering::Relaxed),
    );
    counters.insert("retries".into(), scheduler.retries.load(Ordering::Relaxed));
    counters.insert("tiles".into(), tiles_counter.get());
    counters.insert("fused_algorithms".into(), n_algs as u64);

    let mut reports = Vec::with_capacity(n_algs);
    for (i, alg_outputs) in outputs.into_iter().enumerate() {
        let images = super::shuffle::merge_image_outputs(
            alg_outputs,
            spec.per_image_caps[i],
            spec.report_keypoints,
        );
        if images.len() != n_images {
            return Err(DifetError::Job(format!(
                "{}: merged {} images, bundle has {n_images}",
                spec.algorithms[i],
                images.len()
            )));
        }
        reports.push(JobReport {
            algorithm: spec.algorithms[i].clone(),
            nodes: cfg.cluster.nodes,
            image_count: n_images,
            sim_seconds,
            wall_seconds,
            compute_seconds,
            io_seconds,
            images,
            counters: counters.clone(),
        });
    }
    Ok(reports)
}

/// The mapper body: split read → record decode → tile loop → aggregate.
/// Input I/O happens ONCE regardless of how many algorithms are fused.
/// The payload is one `Vec<MapOutput>` per algorithm (spec order).
#[allow(clippy::too_many_arguments)]
fn map_task(
    cfg: &Config,
    dfs: &Dfs,
    executor: &dyn TileExecutor,
    spec: &FusedJobSpec,
    hooks: &JobHooks,
    cost: &CostModel,
    metas: &[RecordMeta],
    desc: &TaskDescriptor,
    handle: &TaskHandle,
    node: NodeId,
    tiles_counter: &crate::metrics::Counter,
    tile_hist: &crate::metrics::Histogram,
) -> Result<Option<SlotWork<Vec<Vec<MapOutput>>>>> {
    // Failure injection happens before any work, like a crashed JVM.
    if let Some(f) = &hooks.fail {
        if f(desc.task_id, handle.attempt) {
            return Err(DifetError::Job(format!(
                "injected failure (task {}, attempt {})",
                desc.task_id, handle.attempt
            )));
        }
    }

    let mut io_secs = 0.0f64;
    let mut compute_ns = 0u64;

    // --- input: read this split's byte range from DFS ----------------------
    let (bytes, stats) = dfs.read_range(&spec.bundle_path, desc.byte_start, desc.byte_end, node)?;
    io_secs += cost.split_input(stats.local_bytes, stats.remote_bytes);

    let mut outputs: Vec<Vec<MapOutput>> = vec![
        Vec::with_capacity(desc.last_record - desc.first_record);
        spec.algorithms.len()
    ];
    let total_records = (desc.last_record - desc.first_record).max(1);

    for (done, rec) in (desc.first_record..desc.last_record).enumerate() {
        if handle.cancelled() {
            return Ok(None);
        }
        let rec_off = (metas[rec].offset - desc.byte_start) as usize;
        let (image_id, image, _) = hib::decode_record(&bytes[rec_off..])?;

        let (map_out, tile_compute_ns) = map_one_image(
            executor,
            spec,
            image_id,
            &image,
            handle,
            tiles_counter,
            tile_hist,
        )?;
        let Some(map_out) = map_out else {
            return Ok(None); // cancelled mid-image
        };
        compute_ns += tile_compute_ns;

        // --- output: the paper's mapper step 5 writes the annotated image
        // back to HDFS, once per algorithm (each census is its own
        // artifact, exactly as seven independent jobs would leave).  We
        // store the keypoint summary (real bytes) and model the cost of
        // the image-sized write the paper performs.
        if spec.write_output {
            for (alg, out) in spec.algorithms.iter().zip(&map_out) {
                let summary = serialize_output(out);
                let out_path = format!("{}.out/{alg}/{image_id}", spec.bundle_path);
                dfs.write_file(&out_path, &summary, node)?;
                io_secs += cost.hdfs_write(image.byte_len() as u64, cfg.cluster.replication);
            }
        }
        for (dst, out) in outputs.iter_mut().zip(map_out) {
            dst.push(out);
        }
        handle.report_progress((done + 1) as f64 / total_records as f64);
    }

    let io_ns = (io_secs * 1e9) as u64;
    let overhead_ns = (cost.task_overhead() * 1e9) as u64;
    Ok(Some(SlotWork {
        payload: outputs,
        virtual_ns: overhead_ns + io_ns + compute_ns,
        compute_ns,
        io_ns,
    }))
}

/// Extract one image: tile it, run the executor once per tile (all
/// algorithms fused), merge per algorithm.  Returns one [`MapOutput`]
/// per algorithm, in spec order.
fn map_one_image(
    executor: &dyn TileExecutor,
    spec: &FusedJobSpec,
    image_id: u64,
    image: &Rgba8Image,
    handle: &TaskHandle,
    tiles_counter: &crate::metrics::Counter,
    tile_hist: &crate::metrics::Histogram,
) -> Result<(Option<Vec<MapOutput>>, u64)> {
    let n = spec.algorithms.len();
    let alg_names: Vec<&str> = spec.algorithms.iter().map(|s| s.as_str()).collect();
    let keeps: Vec<usize> = spec
        .per_image_caps
        .iter()
        .map(|&cap| mapper_retention(cap, spec.report_keypoints))
        .collect();
    let mut raw_count = vec![0u64; n];
    let mut descriptor_count = vec![0u64; n];
    let mut keypoints: Vec<Vec<crate::features::Keypoint>> = vec![Vec::new(); n];
    // Descriptor rows parallel to `keypoints` (only filled when the spec
    // keeps them; `None` rows make every re-rank below a plain sort).
    let mut descriptors: Vec<Descriptors> = vec![Descriptors::None; n];
    let mut compute_ns = 0u64;

    for tile in TileIter::new(image.width, image.height) {
        if handle.cancelled() {
            return Ok((None, compute_ns));
        }
        let buf = extract_tile_f32(image, &tile);
        let t0 = std::time::Instant::now();
        let feats_multi = executor.run_tile_multi(&alg_names, &buf, tile.core_local())?;
        let dt = t0.elapsed();
        compute_ns += dt.as_nanos() as u64;
        tile_hist.observe(dt.as_secs_f64());
        tiles_counter.inc();

        for (i, feats) in feats_multi.into_iter().enumerate() {
            raw_count[i] += feats.count;
            descriptor_count[i] += feats.descriptors.len() as u64;
            if spec.keep_descriptors {
                // Extractors emit exactly one row per retained keypoint,
                // in keypoint order, so appending both keeps row i of the
                // batch describing keypoint i.
                descriptors[i].append(feats.descriptors)?;
            }
            for kp in feats.keypoints {
                let (sr, sc) = tile.to_scene(kp.row, kp.col);
                keypoints[i].push(crate::features::Keypoint {
                    row: sr as i32,
                    col: sc as i32,
                    score: kp.score,
                });
            }
            // Keep the buffer bounded: re-rank and truncate when 4× over.
            if keypoints[i].len() > keeps[i] * 4 {
                rank_truncate(&mut keypoints[i], &mut descriptors[i], keeps[i]);
            }
        }
    }

    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let mut kps = std::mem::take(&mut keypoints[i]);
        let mut descs = std::mem::take(&mut descriptors[i]);
        rank_truncate(&mut kps, &mut descs, keeps[i]);
        out.push(MapOutput {
            image_id,
            raw_count: raw_count[i],
            keypoints: kps,
            descriptor_count: descriptor_count[i],
            descriptors: descs,
        });
    }
    Ok((Some(out), compute_ns))
}

// ---------------------------------------------------------------------------
// The registration job: reduce-side scene-pair matching.
// ---------------------------------------------------------------------------

/// Run a registration job over the per-scene censuses a
/// `keep_descriptors` extraction produced: shuffle each scene's
/// keypoints+descriptors into DFS feature files, enumerate scene pairs,
/// and run reduce-side descriptor matching + translation RANSAC on the
/// worker slots through the same [`Scheduler`] the map stage uses — pair
/// tasks get locality (toward the nodes holding the feature files),
/// bounded retries and straggler speculation for free.
///
/// Determinism contract: pair results depend only on the censuses and the
/// spec (per-pair seeds come from [`pair_seed`]), never on which
/// node/slot/attempt ran the pair, so the report is byte-identical across
/// runs and matches the sequential `match_descriptors` +
/// `ransac_translation` baseline exactly.
pub fn run_registration_job(
    cfg: &Config,
    dfs: &Dfs,
    censuses: &[ImageCensus],
    spec: &RegistrationSpec,
    registry: &Registry,
    hooks: &JobHooks,
) -> Result<RegistrationReport> {
    let wall = Stopwatch::start();
    let cost = CostModel::new(&cfg.cluster);

    let scene_ids: Vec<u64> = censuses.iter().map(|c| c.image_id).collect();
    let pairs = shuffle::enumerate_pairs(&scene_ids, spec.pairs.as_deref())?;
    let by_id: std::collections::BTreeMap<u64, &ImageCensus> =
        censuses.iter().map(|c| (c.image_id, c)).collect();
    if by_id.len() != censuses.len() {
        return Err(DifetError::Job("duplicate image ids in census set".into()));
    }

    // ---- shuffle: write each referenced scene's features into DFS --------
    // (the descriptor payloads the paper-shaped map stage would have left
    // behind; pair reducers fetch them with real locality accounting.)
    let mut needed: Vec<u64> = pairs.iter().flat_map(|&(a, b)| [a, b]).collect();
    needed.sort_unstable();
    needed.dedup();
    let feature_path =
        |id: u64| format!("{}/{}/{id}", spec.feature_dir, spec.algorithm);
    let mut shuffle_write_secs = vec![0.0f64; cfg.cluster.nodes];
    for &id in &needed {
        let census = by_id[&id];
        let bytes = shuffle::encode_features(census);
        // Spread feature files round-robin, like reducer partitions.
        let writer = NodeId(id as usize % cfg.cluster.nodes);
        dfs.write_file(&feature_path(id), &bytes, writer)?;
        shuffle_write_secs[writer.0] +=
            cost.hdfs_write(bytes.len() as u64, cfg.cluster.replication);
    }
    let shuffle_secs = shuffle_write_secs.iter().cloned().fold(0.0, f64::max);

    // ---- plan: one reduce task per scene pair ----------------------------
    let tasks: Vec<PairTask> = pairs
        .iter()
        .enumerate()
        .map(|(pair_id, &(a, b))| {
            let (path_a, path_b) = (feature_path(a), feature_path(b));
            let mut preferred = Vec::new();
            for path in [&path_a, &path_b] {
                if let Ok(meta) = dfs.namenode().file_meta(path) {
                    if let Ok(nodes) = dfs.locate_range(path, 0, meta.len) {
                        for n in nodes {
                            if !preferred.contains(&n) {
                                preferred.push(n);
                            }
                        }
                    }
                }
            }
            PairTask { pair_id, image_a: a, image_b: b, path_a, path_b, preferred_nodes: preferred }
        })
        .collect();
    let n_pairs = tasks.len();

    let scheduler: Scheduler<PairTask> = Scheduler::new(tasks, &cfg.scheduler);
    let results: Mutex<Vec<Option<PairResult>>> = Mutex::new(vec![None; n_pairs]);
    let pairs_counter = registry.counter("pairs_processed");
    let pair_hist = registry.histogram("pair_latency");

    let totals = run_slots(
        &cfg.cluster,
        &scheduler,
        |task: &PairTask, handle, node| {
            let work = reduce_pair(dfs, spec, hooks, &cost, task, handle, node)?;
            if let Some(w) = &work {
                pair_hist.observe(w.compute_ns as f64 * 1e-9);
            }
            Ok(work)
        },
        |task, result| {
            pairs_counter.inc();
            results.lock().unwrap()[task.pair_id] = Some(result);
        },
    );

    if let Some(reason) = scheduler.abort_reason() {
        return Err(DifetError::Job(reason));
    }

    let results: Vec<PairResult> = results
        .into_inner()
        .unwrap()
        .into_iter()
        .collect::<Option<Vec<_>>>()
        .ok_or_else(|| DifetError::Job("registration pair lost its result".into()))?;

    let mut counters = std::collections::BTreeMap::new();
    counters.insert("pairs".into(), n_pairs as u64);
    counters.insert(
        "registered_pairs".into(),
        results.iter().filter(|p| p.translation.is_some()).count() as u64,
    );
    counters.insert(
        "data_local_tasks".into(),
        scheduler.data_local_tasks.load(Ordering::Relaxed),
    );
    counters.insert(
        "rack_remote_tasks".into(),
        scheduler.rack_remote_tasks.load(Ordering::Relaxed),
    );
    counters.insert(
        "speculative_launches".into(),
        scheduler.speculative_launches.load(Ordering::Relaxed),
    );
    counters.insert("retries".into(), scheduler.retries.load(Ordering::Relaxed));

    Ok(RegistrationReport {
        algorithm: spec.algorithm.clone(),
        nodes: cfg.cluster.nodes,
        pair_count: n_pairs,
        sim_seconds: cost.job_startup() + shuffle_secs + totals.max_slot_ns as f64 * 1e-9,
        wall_seconds: wall.elapsed_secs(),
        compute_seconds: totals.compute_ns as f64 * 1e-9,
        io_seconds: totals.io_ns as f64 * 1e-9,
        pairs: results,
        counters,
    })
}

/// The reducer body: fetch both feature files, match descriptors
/// (chunked, reporting progress and honouring cancellation so a losing
/// speculative twin dies mid-scan), then RANSAC the translation.
fn reduce_pair(
    dfs: &Dfs,
    spec: &RegistrationSpec,
    hooks: &JobHooks,
    cost: &CostModel,
    task: &PairTask,
    handle: &TaskHandle,
    node: NodeId,
) -> Result<Option<SlotWork<PairResult>>> {
    if let Some(f) = &hooks.fail {
        if f(task.pair_id, handle.attempt) {
            return Err(DifetError::Job(format!(
                "injected failure (pair {}, attempt {})",
                task.pair_id, handle.attempt
            )));
        }
    }

    // --- shuffle input: fetch both scenes' features -----------------------
    let (bytes_a, stats_a) = dfs.read_file(&task.path_a, node)?;
    let (bytes_b, stats_b) = dfs.read_file(&task.path_b, node)?;
    let io_secs = cost.split_input(
        stats_a.local_bytes + stats_b.local_bytes,
        stats_a.remote_bytes + stats_b.remote_bytes,
    );
    let (id_a, kps_a, desc_a) = shuffle::decode_features(&bytes_a)?;
    let (id_b, kps_b, desc_b) = shuffle::decode_features(&bytes_b)?;
    if (id_a, id_b) != (task.image_a, task.image_b) {
        return Err(DifetError::Job(format!(
            "feature file routing mixup: wanted ({}, {}), got ({id_a}, {id_b})",
            task.image_a, task.image_b
        )));
    }

    // --- reduce: match + register ----------------------------------------
    let t0 = std::time::Instant::now();
    const MATCH_CHUNK: usize = 64;
    let Some(matches) =
        match_descriptors_while(&desc_a, &desc_b, spec.ratio, MATCH_CHUNK, &mut |done, total| {
            handle.report_progress(done as f64 / total.max(1) as f64);
            !handle.cancelled()
        })
    else {
        return Ok(None); // cancelled: the twin won
    };
    if handle.cancelled() {
        return Ok(None);
    }
    let translation = if matches.len() >= spec.min_matches {
        ransac_translation(
            &kps_a,
            &kps_b,
            &matches,
            spec.tolerance_px,
            spec.ransac_iters,
            pair_seed(spec.seed, task.image_a, task.image_b),
        )
    } else {
        None
    };
    let compute_ns = t0.elapsed().as_nanos() as u64;

    let io_ns = (io_secs * 1e9) as u64;
    let overhead_ns = (cost.task_overhead() * 1e9) as u64;
    Ok(Some(SlotWork {
        payload: PairResult {
            image_a: task.image_a,
            image_b: task.image_b,
            matches: matches.len(),
            translation,
        },
        virtual_ns: overhead_ns + io_ns + compute_ns,
        compute_ns,
        io_ns,
    }))
}

// ---------------------------------------------------------------------------
// The mosaic job: canvas-tile compositing over aligned scenes.
// ---------------------------------------------------------------------------

/// Run a mosaic job: shuffle the scene images into CRC-guarded DFS files,
/// split the canvas into tile-shaped work units on the same generic
/// [`Scheduler`] (the third `WorkItem` shape — locality toward the nodes
/// holding the overlapping scene files, bounded retries, straggler
/// speculation), and composite each tile with the blend the spec names.
///
/// Determinism contract: every canvas pixel is a pure function of the
/// scenes covering it and the blend mode
/// ([`crate::mosaic::composite_rect_while`] accumulates in ascending
/// scene-id order), so the assembled mosaic is byte-identical to
/// [`crate::mosaic::composite_sequential`] regardless of node count,
/// tiling, retries or speculation histories.
///
/// Returns the job report (seam metrics included) and the composited
/// canvas.  Seam diagnostics land in `registry` too: an `overlap_rms`
/// histogram and the `mosaic_max_cycle_residual` gauge.
pub fn run_mosaic_job(
    cfg: &Config,
    dfs: &Dfs,
    scenes: &[(u64, Rgba8Image)],
    alignment: &crate::mosaic::GlobalAlignment,
    spec: &MosaicSpec,
    registry: &Registry,
    hooks: &JobHooks,
) -> Result<(MosaicReport, Rgba8Image)> {
    let wall = Stopwatch::start();
    let cost = CostModel::new(&cfg.cluster);

    // ---- layout: solved positions → integer canvas placements ------------
    let dims: Vec<(u64, usize, usize)> = scenes
        .iter()
        .map(|(id, img)| (*id, img.width, img.height))
        .collect();
    // (layout rejects duplicate scene ids, so `by_id` is lossless.)
    let canvas = crate::mosaic::layout(alignment, &dims)?;
    let by_id: std::collections::BTreeMap<u64, &Rgba8Image> =
        scenes.iter().map(|(id, img)| (*id, img)).collect();

    // ---- shuffle: write each scene image into DFS -------------------------
    // (the canvas-tile reducers fetch them with real locality accounting;
    // payloads ride the hib codec under the storage compression policy.)
    let scene_codec = if cfg.storage.compress {
        crate::hib::Codec::Deflate
    } else {
        crate::hib::Codec::Raw
    };
    let scene_path = |id: u64| format!("{}/{id}", spec.scene_dir);
    let mut shuffle_write_secs = vec![0.0f64; cfg.cluster.nodes];
    for (id, img) in scenes {
        let bytes =
            shuffle::encode_scene(*id, img, scene_codec, cfg.storage.compression_level)?;
        // Spread scene files round-robin, like reducer partitions.
        let writer = NodeId(*id as usize % cfg.cluster.nodes);
        dfs.write_file(&scene_path(*id), &bytes, writer)?;
        shuffle_write_secs[writer.0] +=
            cost.hdfs_write(bytes.len() as u64, cfg.cluster.replication);
    }
    let shuffle_secs = shuffle_write_secs.iter().cloned().fold(0.0, f64::max);

    // ---- plan: one work unit per canvas tile ------------------------------
    let tasks: Vec<CanvasTile> = crate::mosaic::tile_rects(&canvas, spec.canvas_tile)
        .into_iter()
        .enumerate()
        .map(|(tile_id, rect)| {
            let scene_ids = crate::mosaic::scenes_in_rect(&canvas, rect);
            let scene_paths: Vec<String> = scene_ids.iter().map(|&id| scene_path(id)).collect();
            let mut preferred = Vec::new();
            for path in &scene_paths {
                if let Ok(meta) = dfs.namenode().file_meta(path) {
                    if let Ok(nodes) = dfs.locate_range(path, 0, meta.len) {
                        for n in nodes {
                            if !preferred.contains(&n) {
                                preferred.push(n);
                            }
                        }
                    }
                }
            }
            CanvasTile { tile_id, rect, scene_ids, scene_paths, preferred_nodes: preferred }
        })
        .collect();
    let n_tiles = tasks.len();

    let scheduler: Scheduler<CanvasTile> = Scheduler::new(tasks, &cfg.scheduler);
    let results: Mutex<Vec<Option<Vec<u8>>>> = Mutex::new(vec![None; n_tiles]);
    let tiles_counter = registry.counter("canvas_tiles");
    let tile_hist = registry.histogram("canvas_tile_latency");

    let totals = run_slots(
        &cfg.cluster,
        &scheduler,
        |task: &CanvasTile, handle, node| {
            let work = mosaic_tile(dfs, spec, hooks, &cost, &canvas, task, handle, node)?;
            if let Some(w) = &work {
                tile_hist.observe(w.compute_ns as f64 * 1e-9);
            }
            Ok(work)
        },
        |task, pixels| {
            tiles_counter.inc();
            results.lock().unwrap()[task.tile_id] = Some(pixels);
        },
    );

    if let Some(reason) = scheduler.abort_reason() {
        return Err(DifetError::Job(reason));
    }

    // ---- assemble: tile pixels → one canvas -------------------------------
    let tiles: Vec<Vec<u8>> = results
        .into_inner()
        .unwrap()
        .into_iter()
        .collect::<Option<Vec<_>>>()
        .ok_or_else(|| DifetError::Job("mosaic tile lost its result".into()))?;
    let mut mosaic = Rgba8Image::new(canvas.width, canvas.height);
    for (rect, px) in crate::mosaic::tile_rects(&canvas, spec.canvas_tile)
        .into_iter()
        .zip(&tiles)
    {
        let [r0, r1, c0, c1] = rect;
        mosaic.blit(r0, c0, r1 - r0, c1 - c0, px);
    }

    // ---- seam diagnostics -------------------------------------------------
    let overlaps = crate::mosaic::overlap_stats(&canvas, &by_id)?;
    let rms_hist = registry.histogram("overlap_rms");
    for o in &overlaps {
        rms_hist.observe(o.rms);
    }
    registry
        .gauge("mosaic_max_cycle_residual")
        .set(alignment.max_residual());

    let mut counters = std::collections::BTreeMap::new();
    counters.insert("tiles".into(), n_tiles as u64);
    counters.insert("scenes".into(), scenes.len() as u64);
    counters.insert("overlaps".into(), overlaps.len() as u64);
    counters.insert(
        "data_local_tasks".into(),
        scheduler.data_local_tasks.load(Ordering::Relaxed),
    );
    counters.insert(
        "rack_remote_tasks".into(),
        scheduler.rack_remote_tasks.load(Ordering::Relaxed),
    );
    counters.insert(
        "speculative_launches".into(),
        scheduler.speculative_launches.load(Ordering::Relaxed),
    );
    counters.insert("retries".into(), scheduler.retries.load(Ordering::Relaxed));

    let report = MosaicReport {
        nodes: cfg.cluster.nodes,
        scene_count: scenes.len(),
        canvas_width: canvas.width,
        canvas_height: canvas.height,
        tile_count: n_tiles,
        blend: spec.blend,
        sim_seconds: cost.job_startup() + shuffle_secs + totals.max_slot_ns as f64 * 1e-9,
        wall_seconds: wall.elapsed_secs(),
        compute_seconds: totals.compute_ns as f64 * 1e-9,
        io_seconds: totals.io_ns as f64 * 1e-9,
        overlaps,
        max_cycle_residual: alignment.max_residual(),
        rms_cycle_residual: alignment.rms_residual(),
        counters,
    };
    Ok((report, mosaic))
}

/// The mosaic work-unit body: fetch the scenes overlapping this canvas
/// tile from DFS, decode them (CRC-guarded), composite the rect with
/// row-level progress reporting and cooperative cancellation (a losing
/// speculative twin dies mid-render).
#[allow(clippy::too_many_arguments)]
fn mosaic_tile(
    dfs: &Dfs,
    spec: &MosaicSpec,
    hooks: &JobHooks,
    cost: &CostModel,
    canvas: &crate::mosaic::Canvas,
    task: &CanvasTile,
    handle: &TaskHandle,
    node: NodeId,
) -> Result<Option<SlotWork<Vec<u8>>>> {
    if let Some(f) = &hooks.fail {
        if f(task.tile_id, handle.attempt) {
            return Err(DifetError::Job(format!(
                "injected failure (tile {}, attempt {})",
                task.tile_id, handle.attempt
            )));
        }
    }

    // --- shuffle input: fetch only the scenes overlapping this rect -------
    let mut io_secs = 0.0f64;
    let mut tile_scenes: Vec<(u64, Rgba8Image)> = Vec::with_capacity(task.scene_paths.len());
    for (expected_id, path) in task.scene_ids.iter().zip(&task.scene_paths) {
        if handle.cancelled() {
            return Ok(None);
        }
        let (bytes, stats) = dfs.read_file(path, node)?;
        io_secs += cost.split_input(stats.local_bytes, stats.remote_bytes);
        let (id, img) = shuffle::decode_scene(&bytes)?;
        if id != *expected_id {
            return Err(DifetError::Job(format!(
                "scene file routing mixup: wanted {expected_id}, got {id}"
            )));
        }
        tile_scenes.push((id, img));
    }
    let by_id: std::collections::BTreeMap<u64, &Rgba8Image> =
        tile_scenes.iter().map(|(id, img)| (*id, img)).collect();

    // --- reduce: composite the rect ---------------------------------------
    let t0 = std::time::Instant::now();
    let Some(pixels) =
        crate::mosaic::composite_rect_while(canvas, &by_id, spec.blend, task.rect, &mut |done,
                 total| {
            handle.report_progress(done as f64 / total.max(1) as f64);
            !handle.cancelled()
        })?
    else {
        return Ok(None); // cancelled: the twin won
    };
    let compute_ns = t0.elapsed().as_nanos() as u64;

    let io_ns = (io_secs * 1e9) as u64;
    let overhead_ns = (cost.task_overhead() * 1e9) as u64;
    Ok(Some(SlotWork {
        payload: pixels,
        virtual_ns: overhead_ns + io_ns + compute_ns,
        compute_ns,
        io_ns,
    }))
}

// ---------------------------------------------------------------------------
// The vector job: band-tile connected-component labeling over a mask.
// ---------------------------------------------------------------------------

/// Run an object-extraction labeling job: shuffle the segmented mask
/// into DFS (1 byte/pixel, header-free, so band workers fetch their rows
/// as one contiguous range read), split it into full-width band units on
/// the same generic [`Scheduler`] (the fourth `WorkItem` shape —
/// locality toward the nodes holding the band's blocks, bounded retries,
/// straggler speculation), label each band locally, route the tile
/// labels back through CRC-guarded DFS files
/// ([`shuffle::encode_labels`]), and stitch them into global object ids
/// with the reduce-side union-find merge.
///
/// Determinism contract: tile-local components are keyed by the global
/// row-major index of their first pixel and final object ids ascend with
/// each merged object's minimum key
/// ([`crate::vector::merge_tile_labels`]), so — unlike RANSAC pairs — no
/// per-pair seeds are even needed: the merged raster and object table
/// are bit-identical to [`crate::vector::label_sequential`] at any node
/// count, band size, and across retry/speculation histories.
///
/// Returns the job report plus the merged label raster and object table.
/// Diagnostics land in `registry` too: the `objects_extracted` counter
/// and the `vector_max_merge_residual` gauge.
pub fn run_vector_job(
    cfg: &Config,
    dfs: &Dfs,
    mask: &crate::vector::Mask,
    spec: &super::job::VectorSpec,
    registry: &Registry,
    hooks: &JobHooks,
) -> Result<(
    super::job::VectorReport,
    crate::vector::Labels,
    Vec<crate::vector::ObjectStats>,
)> {
    let wall = Stopwatch::start();
    let cost = CostModel::new(&cfg.cluster);
    if mask.width == 0 || mask.height == 0 {
        return Err(DifetError::Job("vector job: empty mask".into()));
    }
    if mask.data.len() != mask.width * mask.height {
        return Err(DifetError::Job(format!(
            "vector job: mask raster has {} cells, {}×{} needs {}",
            mask.data.len(),
            mask.width,
            mask.height,
            mask.width * mask.height
        )));
    }

    // ---- shuffle: write the mask raster into DFS --------------------------
    dfs.write_file(&spec.mask_path, &mask.data, NodeId(0))?;
    let shuffle_secs = cost.hdfs_write(mask.data.len() as u64, cfg.cluster.replication);

    // ---- plan: one work unit per full-width mask band ---------------------
    let tasks: Vec<super::job::LabelTile> =
        crate::vector::band_rects(mask.width, mask.height, spec.band_rows)
            .into_iter()
            .enumerate()
            .map(|(tile_id, rect)| {
                let byte_start = (rect[0] * mask.width) as u64;
                let byte_end = (rect[1] * mask.width) as u64;
                let preferred = dfs
                    .locate_range(&spec.mask_path, byte_start, byte_end)
                    .unwrap_or_default();
                super::job::LabelTile {
                    tile_id,
                    rect,
                    byte_start,
                    byte_end,
                    mask_path: spec.mask_path.clone(),
                    labels_path: format!("{}/{tile_id}", spec.labels_dir),
                    preferred_nodes: preferred,
                }
            })
            .collect();
    let n_tiles = tasks.len();
    let labels_paths: Vec<String> = tasks.iter().map(|t| t.labels_path.clone()).collect();

    let scheduler: Scheduler<super::job::LabelTile> = Scheduler::new(tasks, &cfg.scheduler);
    let done: Mutex<Vec<bool>> = Mutex::new(vec![false; n_tiles]);
    let tiles_counter = registry.counter("label_tiles");
    let tile_hist = registry.histogram("label_tile_latency");

    let totals = run_slots(
        &cfg.cluster,
        &scheduler,
        |task: &super::job::LabelTile, handle, node| {
            let work = label_tile(cfg, dfs, hooks, &cost, task, handle, node)?;
            if let Some(w) = &work {
                tile_hist.observe(w.compute_ns as f64 * 1e-9);
            }
            Ok(work)
        },
        |task, ()| {
            tiles_counter.inc();
            done.lock().unwrap()[task.tile_id] = true;
        },
    );

    if let Some(reason) = scheduler.abort_reason() {
        return Err(DifetError::Job(reason));
    }
    if !done.into_inner().unwrap().into_iter().all(|d| d) {
        return Err(DifetError::Job("vector tile lost its result".into()));
    }

    // ---- reduce: fetch the shuffled tile labels, merge the seams ----------
    let mut tiles = Vec::with_capacity(n_tiles);
    for (tile_id, path) in labels_paths.iter().enumerate() {
        let (bytes, _) = dfs.read_file(path, NodeId(0))?;
        let (id, tile) = shuffle::decode_labels(&bytes)?;
        if id != tile_id as u64 {
            return Err(DifetError::Job(format!(
                "label file routing mixup: wanted {tile_id}, got {id}"
            )));
        }
        tiles.push(tile);
    }
    let (labels, objects, mstats) =
        crate::vector::merge_tile_labels(mask.width, mask.height, &tiles)?;

    registry
        .gauge("vector_max_merge_residual")
        .set(mstats.max_merge_residual() as f64);
    registry.counter("objects_extracted").add(objects.len() as u64);

    let mut counters = std::collections::BTreeMap::new();
    counters.insert("tiles".into(), n_tiles as u64);
    counters.insert("objects".into(), objects.len() as u64);
    counters.insert("seam_unions".into(), mstats.seam_unions);
    counters.insert("max_merge_residual".into(), mstats.max_merge_residual());
    counters.insert(
        "data_local_tasks".into(),
        scheduler.data_local_tasks.load(Ordering::Relaxed),
    );
    counters.insert(
        "rack_remote_tasks".into(),
        scheduler.rack_remote_tasks.load(Ordering::Relaxed),
    );
    counters.insert(
        "speculative_launches".into(),
        scheduler.speculative_launches.load(Ordering::Relaxed),
    );
    counters.insert("retries".into(), scheduler.retries.load(Ordering::Relaxed));

    let report = super::job::VectorReport {
        nodes: cfg.cluster.nodes,
        width: mask.width,
        height: mask.height,
        tile_count: n_tiles,
        object_count: objects.len(),
        foreground_px: mask.foreground(),
        max_merge_residual: mstats.max_merge_residual(),
        seam_unions: mstats.seam_unions,
        sim_seconds: cost.job_startup() + shuffle_secs + totals.max_slot_ns as f64 * 1e-9,
        wall_seconds: wall.elapsed_secs(),
        compute_seconds: totals.compute_ns as f64 * 1e-9,
        io_seconds: totals.io_ns as f64 * 1e-9,
        counters,
    };
    Ok((report, labels, objects))
}

/// The label work-unit body: fetch this band's mask rows from DFS (one
/// contiguous range read), run tile-local CCL with row-level progress
/// reporting and cooperative cancellation (a losing speculative twin
/// dies mid-scan), and shuffle the encoded tile labels back into a
/// CRC-guarded DFS file for the merge stage.
fn label_tile(
    cfg: &Config,
    dfs: &Dfs,
    hooks: &JobHooks,
    cost: &CostModel,
    task: &super::job::LabelTile,
    handle: &TaskHandle,
    node: NodeId,
) -> Result<Option<SlotWork<()>>> {
    if let Some(f) = &hooks.fail {
        if f(task.tile_id, handle.attempt) {
            return Err(DifetError::Job(format!(
                "injected failure (tile {}, attempt {})",
                task.tile_id, handle.attempt
            )));
        }
    }

    // --- input: this band's rows of the shuffled mask ---------------------
    let (bytes, stats) =
        dfs.read_range(&task.mask_path, task.byte_start, task.byte_end, node)?;
    let mut io_secs = cost.split_input(stats.local_bytes, stats.remote_bytes);
    let [r0, r1, c0, c1] = task.rect;
    let (rows, width) = (r1 - r0, c1 - c0);
    if c0 != 0 || bytes.len() != rows * width {
        return Err(DifetError::Job(format!(
            "mask band {}: got {} bytes, rect {:?} needs {}",
            task.tile_id,
            bytes.len(),
            task.rect,
            rows * width
        )));
    }
    let band = crate::vector::Mask { width, height: rows, data: bytes };

    // --- label the band locally -------------------------------------------
    let t0 = std::time::Instant::now();
    let Some(local) =
        crate::vector::label_rect_while(&band, [0, rows, 0, width], &mut |done, total| {
            handle.report_progress(done as f64 / total.max(1) as f64);
            !handle.cancelled()
        })?
    else {
        return Ok(None); // cancelled: the twin won
    };
    let tile = local.offset_rows(r0);
    let compute_ns = t0.elapsed().as_nanos() as u64;
    if handle.cancelled() {
        return Ok(None);
    }

    // --- output: shuffle the tile labels into DFS --------------------------
    // (bit-identical across attempts, so a retry or losing twin rewriting
    // the same path is harmless.)
    let encoded = shuffle::encode_labels(task.tile_id as u64, &tile);
    dfs.write_file(&task.labels_path, &encoded, node)?;
    io_secs += cost.hdfs_write(encoded.len() as u64, cfg.cluster.replication);

    let io_ns = (io_secs * 1e9) as u64;
    let overhead_ns = (cost.task_overhead() * 1e9) as u64;
    Ok(Some(SlotWork {
        payload: (),
        virtual_ns: overhead_ns + io_ns + compute_ns,
        compute_ns,
        io_ns,
    }))
}

/// Serialize a mapper output (the record written back to DFS).
fn serialize_output(out: &MapOutput) -> Vec<u8> {
    use byteorder::{ByteOrder, LittleEndian as LE};
    let mut buf = Vec::with_capacity(16 + out.keypoints.len() * 12);
    let mut u64b = [0u8; 8];
    LE::write_u64(&mut u64b, out.image_id);
    buf.extend_from_slice(&u64b);
    LE::write_u64(&mut u64b, out.raw_count);
    buf.extend_from_slice(&u64b);
    let mut u32b = [0u8; 4];
    LE::write_u32(&mut u32b, out.keypoints.len() as u32);
    buf.extend_from_slice(&u32b);
    for kp in &out.keypoints {
        LE::write_u32(&mut u32b, kp.row as u32);
        buf.extend_from_slice(&u32b);
        LE::write_u32(&mut u32b, kp.col as u32);
        buf.extend_from_slice(&u32b);
        LE::write_u32(&mut u32b, kp.score.to_bits());
        buf.extend_from_slice(&u32b);
    }
    buf
}
