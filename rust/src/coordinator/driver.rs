//! The job driver ("jobtracker"): plan → schedule → execute → merge.
//!
//! One call to [`run_job`] is one MapReduce job of the paper: a feature
//! extraction pass of one algorithm over one HIB bundle.  Real compute
//! (PJRT tile executions) runs on real worker threads (one per map slot,
//! `nodes × slots_per_node` total); disk/network time is *modeled* by
//! [`crate::cluster::CostModel`] and accumulated per slot.  The reported
//! job time is
//!
//! ```text
//! sim_seconds = job_startup + max_over_slots( Σ task_overhead
//!                                            + modeled_io + measured_compute )
//! ```
//!
//! which is the quantity comparable to the paper's Table 1 cells (see
//! EXPERIMENTS.md for the measured-vs-modeled breakdown of every column).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::cluster::CostModel;
use crate::config::Config;
use crate::dfs::{Dfs, NodeId};
use crate::features::{self, Algorithm, GrayImage};
use crate::hib::{self, BundleReader, RecordMeta};
use crate::imagery::tiler::{extract_tile_f32, TileIter};
use crate::imagery::Rgba8Image;
use crate::metrics::Registry;
use crate::runtime::TileFeatures;
use crate::util::{DifetError, Result, Stopwatch};

use super::job::{JobReport, JobSpec, MapOutput};
use super::scheduler::{Assignment, Scheduler, TaskDescriptor, TaskHandle};

/// Anything that can extract features from one tile: the PJRT engine in
/// production, the pure-Rust baseline as hermetic fallback.
pub trait TileExecutor: Sync {
    fn run_tile(&self, alg: &str, tile: &[f32], core: [i32; 4]) -> Result<TileFeatures>;
    /// Executor label for reports ("pjrt" / "native").
    fn label(&self) -> &'static str;
}

impl TileExecutor for crate::runtime::Engine {
    fn run_tile(&self, alg: &str, tile: &[f32], core: [i32; 4]) -> Result<TileFeatures> {
        self.run(alg, tile, core)
    }
    fn label(&self) -> &'static str {
        "pjrt"
    }
}

/// Pure-Rust executor (`crate::features`), used when artifacts are absent
/// and as the sequential-baseline compute body.
pub struct NativeExecutor;

impl TileExecutor for NativeExecutor {
    fn run_tile(&self, alg: &str, tile: &[f32], core: [i32; 4]) -> Result<TileFeatures> {
        let algorithm = Algorithm::parse(alg)?;
        let gray = GrayImage::from_tile_f32(tile, crate::TILE, crate::TILE);
        let cap = features::params::topk(alg);
        let ex = features::extract(
            algorithm,
            &gray,
            (
                core[0].max(0) as usize,
                core[1].max(0) as usize,
                core[2].max(0) as usize,
                core[3].max(0) as usize,
            ),
            cap,
        );
        Ok(TileFeatures {
            count: ex.count,
            keypoints: ex.keypoints,
            descriptors: ex.descriptors,
        })
    }
    fn label(&self) -> &'static str {
        "native"
    }
}

/// Test hooks: deterministic failure injection.
#[derive(Default)]
pub struct JobHooks {
    /// `fail(task_id, attempt)` → should this attempt die?
    #[allow(clippy::type_complexity)]
    pub fail: Option<Box<dyn Fn(usize, usize) -> bool + Sync>>,
}

/// Run one extraction job on the simulated cluster.
pub fn run_job(
    cfg: &Config,
    dfs: &Dfs,
    executor: &dyn TileExecutor,
    spec: &JobSpec,
    registry: &Registry,
    hooks: &JobHooks,
) -> Result<JobReport> {
    let wall = Stopwatch::start();
    let cost = CostModel::new(&cfg.cluster);

    // ---- plan: read the bundle index, compute record-aligned splits ----
    // (jobtracker-side planning; its I/O is part of the modeled startup.)
    let (bundle_bytes, _) = dfs.read_file(&spec.bundle_path, NodeId(0))?;
    let (tasks, metas) = {
        let reader = BundleReader::open(&bundle_bytes)?;
        let metas: Vec<RecordMeta> = reader.metas().to_vec();
        // HIPI semantics (paper §3): one mapper per image.  A 1-byte split
        // target makes every record its own split; block-sized splits are
        // the plain-Hadoop alternative (ablations A4 measures the trade).
        let split_target = if cfg.scheduler.split_per_image {
            1
        } else {
            cfg.storage.block_size as u64
        };
        let splits = hib::splits(&reader, split_target);
        let mut tasks = Vec::with_capacity(splits.len());
        for (i, s) in splits.iter().enumerate() {
            let preferred = dfs
                .locate_range(&spec.bundle_path, s.byte_start, s.byte_end)
                .unwrap_or_default();
            tasks.push(TaskDescriptor {
                task_id: i,
                first_record: s.first_record,
                last_record: s.last_record,
                byte_start: s.byte_start,
                byte_end: s.byte_end,
                preferred_nodes: preferred,
            });
        }
        (tasks, metas)
    };
    drop(bundle_bytes);
    let n_tasks = tasks.len();
    let n_images = metas.len();

    let scheduler = Scheduler::new(tasks, &cfg.scheduler);
    let outputs: Mutex<Vec<MapOutput>> = Mutex::new(Vec::new());
    let compute_ns = AtomicU64::new(0);
    let io_ns = AtomicU64::new(0);
    let max_slot_ns = AtomicU64::new(0);
    let tiles_counter = registry.counter("tiles_processed");
    let tile_hist = registry.histogram("tile_latency");

    std::thread::scope(|scope| {
        for node in 0..cfg.cluster.nodes {
            for _slot in 0..cfg.cluster.slots_per_node {
                let scheduler = &scheduler;
                let outputs = &outputs;
                let metas = &metas;
                let compute_ns = &compute_ns;
                let io_ns = &io_ns;
                let max_slot_ns = &max_slot_ns;
                let tiles_counter = tiles_counter.clone();
                let tile_hist = tile_hist.clone();
                let cost = &cost;
                scope.spawn(move || {
                    let mut slot_virtual_ns = 0u64;
                    loop {
                        match scheduler.next_assignment(NodeId(node)) {
                            Assignment::Done => break,
                            Assignment::Run(desc, handle) => {
                                match map_task(
                                    cfg, dfs, executor, spec, hooks, cost, metas, &desc,
                                    &handle, NodeId(node), &tiles_counter, &tile_hist,
                                ) {
                                    Ok(Some(task_out)) => {
                                        slot_virtual_ns += task_out.virtual_ns;
                                        compute_ns.fetch_add(task_out.compute_ns, Ordering::Relaxed);
                                        io_ns.fetch_add(task_out.io_ns, Ordering::Relaxed);
                                        if scheduler.report_success(&handle) {
                                            outputs.lock().unwrap().extend(task_out.outputs);
                                        }
                                    }
                                    Ok(None) => scheduler.report_cancelled(&handle),
                                    Err(e) => scheduler.report_failure(&handle, &e.to_string()),
                                }
                            }
                        }
                    }
                    max_slot_ns.fetch_max(slot_virtual_ns, Ordering::Relaxed);
                });
            }
        }
    });

    if let Some(reason) = scheduler.abort_reason() {
        return Err(DifetError::Job(reason));
    }

    let outputs = outputs.into_inner().unwrap();
    let images = super::shuffle::merge_image_outputs(
        outputs,
        spec.per_image_cap,
        spec.report_keypoints,
    );
    if images.len() != n_images {
        return Err(DifetError::Job(format!(
            "merged {} images, bundle has {n_images}",
            images.len()
        )));
    }

    let sim_seconds = cost.job_startup() + max_slot_ns.load(Ordering::Relaxed) as f64 * 1e-9;
    let mut counters = std::collections::BTreeMap::new();
    counters.insert("tasks".into(), n_tasks as u64);
    counters.insert(
        "data_local_tasks".into(),
        scheduler.data_local_tasks.load(Ordering::Relaxed),
    );
    counters.insert(
        "rack_remote_tasks".into(),
        scheduler.rack_remote_tasks.load(Ordering::Relaxed),
    );
    counters.insert(
        "speculative_launches".into(),
        scheduler.speculative_launches.load(Ordering::Relaxed),
    );
    counters.insert("retries".into(), scheduler.retries.load(Ordering::Relaxed));
    counters.insert("tiles".into(), tiles_counter.get());

    Ok(JobReport {
        algorithm: spec.algorithm.clone(),
        nodes: cfg.cluster.nodes,
        image_count: n_images,
        sim_seconds,
        wall_seconds: wall.elapsed_secs(),
        compute_seconds: compute_ns.load(Ordering::Relaxed) as f64 * 1e-9,
        io_seconds: io_ns.load(Ordering::Relaxed) as f64 * 1e-9,
        images,
        counters,
    })
}

struct TaskOutcome {
    outputs: Vec<MapOutput>,
    /// Virtual time this task adds to its slot (overhead + io + compute).
    virtual_ns: u64,
    compute_ns: u64,
    io_ns: u64,
}

/// The mapper body: split read → record decode → tile loop → aggregate.
#[allow(clippy::too_many_arguments)]
fn map_task(
    cfg: &Config,
    dfs: &Dfs,
    executor: &dyn TileExecutor,
    spec: &JobSpec,
    hooks: &JobHooks,
    cost: &CostModel,
    metas: &[RecordMeta],
    desc: &TaskDescriptor,
    handle: &TaskHandle,
    node: NodeId,
    tiles_counter: &crate::metrics::Counter,
    tile_hist: &crate::metrics::Histogram,
) -> Result<Option<TaskOutcome>> {
    // Failure injection happens before any work, like a crashed JVM.
    if let Some(f) = &hooks.fail {
        if f(desc.task_id, handle.attempt) {
            return Err(DifetError::Job(format!(
                "injected failure (task {}, attempt {})",
                desc.task_id, handle.attempt
            )));
        }
    }

    let mut io_secs = 0.0f64;
    let mut compute_ns = 0u64;

    // --- input: read this split's byte range from DFS ----------------------
    let (bytes, stats) = dfs.read_range(&spec.bundle_path, desc.byte_start, desc.byte_end, node)?;
    io_secs += cost.split_input(stats.local_bytes, stats.remote_bytes);

    let mut outputs = Vec::with_capacity(desc.last_record - desc.first_record);
    let total_records = (desc.last_record - desc.first_record).max(1);

    for (done, rec) in (desc.first_record..desc.last_record).enumerate() {
        if handle.cancelled() {
            return Ok(None);
        }
        let rec_off = (metas[rec].offset - desc.byte_start) as usize;
        let (image_id, image, _) = hib::decode_record(&bytes[rec_off..])?;

        let (map_out, tile_compute_ns) = map_one_image(
            executor,
            &spec.algorithm,
            image_id,
            &image,
            spec.per_image_cap,
            spec.report_keypoints,
            handle,
            tiles_counter,
            tile_hist,
        )?;
        let Some(map_out) = map_out else {
            return Ok(None); // cancelled mid-image
        };
        compute_ns += tile_compute_ns;

        // --- output: the paper's mapper step 5 writes the annotated image
        // back to HDFS.  We store the keypoint summary (real bytes) and
        // model the cost of the image-sized write the paper performs.
        if spec.write_output {
            let summary = serialize_output(&map_out);
            let out_path = format!("{}.out/{}/{image_id}", spec.bundle_path, spec.algorithm);
            dfs.write_file(&out_path, &summary, node)?;
            io_secs += cost.hdfs_write(image.byte_len() as u64, cfg.cluster.replication);
        }
        outputs.push(map_out);
        handle.report_progress((done + 1) as f64 / total_records as f64);
    }

    let io_ns = (io_secs * 1e9) as u64;
    let overhead_ns = (cost.task_overhead() * 1e9) as u64;
    Ok(Some(TaskOutcome {
        outputs,
        virtual_ns: overhead_ns + io_ns + compute_ns,
        compute_ns,
        io_ns,
    }))
}

/// Extract one image: tile it, run the executor per tile, merge.
#[allow(clippy::too_many_arguments)]
fn map_one_image(
    executor: &dyn TileExecutor,
    algorithm: &str,
    image_id: u64,
    image: &Rgba8Image,
    per_image_cap: Option<usize>,
    report_keypoints: usize,
    handle: &TaskHandle,
    tiles_counter: &crate::metrics::Counter,
    tile_hist: &crate::metrics::Histogram,
) -> Result<(Option<MapOutput>, u64)> {
    let mut raw_count = 0u64;
    let mut descriptor_count = 0u64;
    let mut keypoints: Vec<crate::features::Keypoint> = Vec::new();
    let keep = per_image_cap.unwrap_or(report_keypoints).max(report_keypoints);
    let mut compute_ns = 0u64;

    for tile in TileIter::new(image.width, image.height) {
        if handle.cancelled() {
            return Ok((None, compute_ns));
        }
        let buf = extract_tile_f32(image, &tile);
        let t0 = std::time::Instant::now();
        let feats = executor.run_tile(algorithm, &buf, tile.core_local())?;
        let dt = t0.elapsed();
        compute_ns += dt.as_nanos() as u64;
        tile_hist.observe(dt.as_secs_f64());
        tiles_counter.inc();

        raw_count += feats.count;
        descriptor_count += feats.descriptors.len() as u64;
        for kp in feats.keypoints {
            let (sr, sc) = tile.to_scene(kp.row, kp.col);
            keypoints.push(crate::features::Keypoint {
                row: sr as i32,
                col: sc as i32,
                score: kp.score,
            });
        }
        // Keep the buffer bounded: re-rank and truncate when 4× over.
        if keypoints.len() > keep * 4 {
            keypoints.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
            keypoints.truncate(keep);
        }
    }
    keypoints.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
    keypoints.truncate(keep);

    Ok((
        Some(MapOutput {
            image_id,
            raw_count,
            keypoints,
            descriptor_count,
        }),
        compute_ns,
    ))
}

/// Serialize a mapper output (the record written back to DFS).
fn serialize_output(out: &MapOutput) -> Vec<u8> {
    use byteorder::{ByteOrder, LittleEndian as LE};
    let mut buf = Vec::with_capacity(16 + out.keypoints.len() * 12);
    let mut u64b = [0u8; 8];
    LE::write_u64(&mut u64b, out.image_id);
    buf.extend_from_slice(&u64b);
    LE::write_u64(&mut u64b, out.raw_count);
    buf.extend_from_slice(&u64b);
    let mut u32b = [0u8; 4];
    LE::write_u32(&mut u32b, out.keypoints.len() as u32);
    buf.extend_from_slice(&u32b);
    for kp in &out.keypoints {
        LE::write_u32(&mut u32b, kp.row as u32);
        buf.extend_from_slice(&u32b);
        LE::write_u32(&mut u32b, kp.col as u32);
        buf.extend_from_slice(&u32b);
        LE::write_u32(&mut u32b, kp.score.to_bits());
        buf.extend_from_slice(&u32b);
    }
    buf
}

