//! The job driver: executors, failure hooks and the four job entry
//! points, all running on the generic job-DAG runtime.
//!
//! This file used to hold four bespoke scheduling loops (one per job
//! shape).  They are gone: every job is now a [`crate::coordinator::dag`]
//! composition of the [`crate::coordinator::stages`] definitions, and
//! the entry points below are thin single-stage wrappers kept for API
//! stability (the pipelines in `crate::pipeline` compose the multi-stage
//! DAGs directly):
//!
//! * [`run_job`] / [`run_fused_job`] — one [`stages::ExtractStage`];
//!   `run_job` is the single-algorithm case of the fused engine.
//! * [`run_registration_job`] — one [`stages::PairStage`] over censuses
//!   that already exist (feature files shuffled at plan time).
//! * [`run_mosaic_job`] — one [`stages::CompositeStage`] over a solved
//!   alignment.
//! * [`run_vector_job`] — one [`stages::LabelStage`] over a mask.
//!
//! Real compute (tile executions, descriptor matching, compositing,
//! labeling) runs on real worker threads (one per map slot,
//! `nodes × slots_per_node` total); disk/network time is *modeled* by
//! [`crate::cluster::CostModel`] and accumulated per slot.  The reported
//! job time is the DAG's simulated clock
//!
//! ```text
//! sim_seconds = job_startup + max_over_units( completion )
//! ```
//!
//! which for a single-stage DAG is exactly the old per-job quantity
//! comparable to the paper's Table 1 cells (see README §Reproducing the
//! paper's tables for the measured-vs-modeled breakdown, and README
//! §Job-DAG runtime for the multi-stage pipelined/barrier semantics).

use crate::config::Config;
use crate::dfs::Dfs;
use crate::features::{self, Algorithm, GrayImage};
use crate::imagery::Rgba8Image;
use crate::metrics::Registry;
use crate::runtime::TileFeatures;
use crate::util::{DifetError, Result};

use super::dag::{run_dag, ExecMode};
use super::job::{
    FusedJobSpec, JobReport, JobSpec, MosaicReport, MosaicSpec, RegistrationReport,
    RegistrationSpec, VectorReport,
};
use super::stages::{
    AlignSource, CompositeStage, ExtractStage, MaskSource, PairSource, PairStage, LabelStage,
};

/// Anything that can extract features from one tile: the PJRT engine in
/// production, the pure-Rust baseline as hermetic fallback.
pub trait TileExecutor: Sync {
    fn run_tile(&self, alg: &str, tile: &[f32], core: [i32; 4]) -> Result<TileFeatures>;

    /// Run several algorithms over ONE tile, returning results in `algs`
    /// order.  The default loops [`TileExecutor::run_tile`];
    /// [`NativeExecutor`] overrides it with the fused
    /// shared-intermediate pass, which must stay byte-identical to the
    /// loop (asserted by `rust/tests/fused_parity.rs`).
    fn run_tile_multi(
        &self,
        algs: &[&str],
        tile: &[f32],
        core: [i32; 4],
    ) -> Result<Vec<TileFeatures>> {
        algs.iter().map(|a| self.run_tile(a, tile, core)).collect()
    }

    /// Executor label for reports ("pjrt" / "native").
    fn label(&self) -> &'static str;
}

impl TileExecutor for crate::runtime::Engine {
    fn run_tile(&self, alg: &str, tile: &[f32], core: [i32; 4]) -> Result<TileFeatures> {
        self.run(alg, tile, core)
    }
    fn label(&self) -> &'static str {
        "pjrt"
    }
}

/// Pure-Rust executor (`crate::features`), used when artifacts are absent
/// and as the sequential-baseline compute body.
pub struct NativeExecutor;

fn core_tuple(core: [i32; 4]) -> (usize, usize, usize, usize) {
    (
        core[0].max(0) as usize,
        core[1].max(0) as usize,
        core[2].max(0) as usize,
        core[3].max(0) as usize,
    )
}

impl TileExecutor for NativeExecutor {
    fn run_tile(&self, alg: &str, tile: &[f32], core: [i32; 4]) -> Result<TileFeatures> {
        let algorithm = Algorithm::parse(alg)?;
        let gray = GrayImage::from_tile_f32(tile, crate::TILE, crate::TILE);
        let cap = features::params::topk(alg);
        let ex = features::extract(algorithm, &gray, core_tuple(core), cap);
        Ok(TileFeatures {
            count: ex.count,
            keypoints: ex.keypoints,
            descriptors: ex.descriptors,
        })
    }

    /// Fused path: one grayscale conversion and one set of shared
    /// intermediates (structure tensor, FAST ring maps, σ=2 smoothing)
    /// feed every requested algorithm.
    fn run_tile_multi(
        &self,
        algs: &[&str],
        tile: &[f32],
        core: [i32; 4],
    ) -> Result<Vec<TileFeatures>> {
        let parsed = algs
            .iter()
            .map(|a| Algorithm::parse(a))
            .collect::<Result<Vec<Algorithm>>>()?;
        let caps: Vec<usize> = algs.iter().map(|a| features::params::topk(a)).collect();
        let gray = GrayImage::from_tile_f32(tile, crate::TILE, crate::TILE);
        let extractions = features::fused::extract_multi(&parsed, &gray, core_tuple(core), &caps);
        Ok(extractions
            .into_iter()
            .map(|ex| TileFeatures {
                count: ex.count,
                keypoints: ex.keypoints,
                descriptors: ex.descriptors,
            })
            .collect())
    }

    fn label(&self) -> &'static str {
        "native"
    }
}

/// Test hooks: deterministic failure injection, applied to every stage
/// of the DAG (unit ids are stage-local, matching the old per-job ids).
#[derive(Default)]
pub struct JobHooks {
    /// `fail(unit_id, attempt)` → should this attempt die?
    #[allow(clippy::type_complexity)]
    pub fail: Option<Box<dyn Fn(usize, usize) -> bool + Sync>>,
}

/// Run one extraction job on the simulated cluster.
pub fn run_job(
    cfg: &Config,
    dfs: &Dfs,
    executor: &dyn TileExecutor,
    spec: &JobSpec,
    registry: &Registry,
    hooks: &JobHooks,
) -> Result<JobReport> {
    let fused: FusedJobSpec = spec.into();
    let mut reports = run_fused_job(cfg, dfs, executor, &fused, registry, hooks)?;
    reports
        .pop()
        .ok_or_else(|| DifetError::Job("fused engine returned no report".into()))
}

/// Run ONE map pass that extracts every algorithm in `spec`, sharing the
/// split read, record decode, tiling and per-tile intermediates across
/// them.  Returns one [`JobReport`] per algorithm (in `spec.algorithms`
/// order); job-level quantities — `sim_seconds`, `wall_seconds`,
/// `compute_seconds`, `io_seconds`, `counters` — are those of the shared
/// pass and therefore identical across the reports.
pub fn run_fused_job(
    cfg: &Config,
    dfs: &Dfs,
    executor: &dyn TileExecutor,
    spec: &FusedJobSpec,
    registry: &Registry,
    hooks: &JobHooks,
) -> Result<Vec<JobReport>> {
    if spec.algorithms.is_empty() {
        return Ok(Vec::new());
    }
    let stage = ExtractStage::new(cfg, dfs, executor, spec.clone(), registry, hooks)?;
    let dag = run_dag(cfg, &[&stage], ExecMode::from_config(cfg), registry)?;
    stage.reports(&dag.stages[0], dag.sim_seconds, dag.wall_seconds)
}

/// Run a registration job over the per-scene censuses a
/// `keep_descriptors` extraction produced: the stage plan shuffles each
/// scene's keypoints+descriptors into DFS feature files, scene pairs
/// become reduce units, and reduce-side ratio-test matching +
/// translation RANSAC runs on the worker slots.
///
/// Determinism contract: pair results depend only on the censuses and
/// the spec (per-pair seeds come from [`super::job::pair_seed`]), never
/// on which node/slot/attempt ran the pair, so the report is
/// byte-identical across runs and matches the sequential
/// `match_descriptors` + `ransac_translation` baseline exactly.
pub fn run_registration_job(
    cfg: &Config,
    dfs: &Dfs,
    censuses: &[super::job::ImageCensus],
    spec: &RegistrationSpec,
    registry: &Registry,
    hooks: &JobHooks,
) -> Result<RegistrationReport> {
    let stage = PairStage::new(
        cfg,
        dfs,
        spec.clone(),
        PairSource::Censuses(censuses),
        registry,
        hooks,
    );
    let dag = run_dag(cfg, &[&stage], ExecMode::from_config(cfg), registry)?;
    stage.report(&dag.stages[0], dag.sim_seconds, dag.wall_seconds)
}

/// Run a mosaic job: shuffle the scene images into CRC-guarded DFS
/// files, split the canvas into tile-shaped work units, composite each
/// tile with the blend the spec names.
///
/// Determinism contract: every canvas pixel is a pure function of the
/// scenes covering it and the blend mode
/// ([`crate::mosaic::composite_rect_while`] accumulates in ascending
/// scene-id order), so the assembled mosaic is byte-identical to
/// [`crate::mosaic::composite_sequential`] regardless of node count,
/// tiling, retries or speculation histories.
///
/// Returns the job report (seam metrics included) and the composited
/// canvas.  Seam diagnostics land in `registry` too: an `overlap_rms`
/// histogram and the `mosaic_max_cycle_residual` gauge.
pub fn run_mosaic_job(
    cfg: &Config,
    dfs: &Dfs,
    scenes: &[(u64, Rgba8Image)],
    alignment: &crate::mosaic::GlobalAlignment,
    spec: &MosaicSpec,
    registry: &Registry,
    hooks: &JobHooks,
) -> Result<(MosaicReport, Rgba8Image)> {
    let stage = CompositeStage::new(
        cfg,
        dfs,
        super::stages::SceneSource::Given(scenes),
        AlignSource::Given(alignment),
        spec.clone(),
        registry,
        hooks,
    );
    let dag = run_dag(cfg, &[&stage], ExecMode::from_config(cfg), registry)?;
    let report = stage.report(&dag.stages[0], dag.sim_seconds, dag.wall_seconds);
    let mosaic = stage.mosaic()?;
    Ok((report, mosaic))
}

/// Run an object-extraction labeling job: shuffle the segmented mask
/// into DFS (1 byte/pixel, header-free, so band workers fetch their
/// rows as one contiguous range read), split it into full-width band
/// units, label each band locally, route the tile labels back through
/// CRC-guarded DFS files, and stitch them into global object ids with
/// the reduce-side union-find merge.
///
/// Determinism contract: tile-local components are keyed by the global
/// row-major index of their first pixel and final object ids ascend
/// with each merged object's minimum key
/// ([`crate::vector::merge_tile_labels`]), so the merged raster and
/// object table are bit-identical to
/// [`crate::vector::label_sequential`] at any node count, band size,
/// and across retry/speculation histories.
///
/// Returns the job report plus the merged label raster and object
/// table.  Diagnostics land in `registry` too: the `objects_extracted`
/// counter and the `vector_max_merge_residual` gauge.
pub fn run_vector_job(
    cfg: &Config,
    dfs: &Dfs,
    mask: &crate::vector::Mask,
    spec: &super::job::VectorSpec,
    registry: &Registry,
    hooks: &JobHooks,
) -> Result<(
    VectorReport,
    crate::vector::Labels,
    Vec<crate::vector::ObjectStats>,
)> {
    let stage = LabelStage::new(
        cfg,
        dfs,
        spec.clone(),
        MaskSource::Given(mask),
        registry,
        hooks,
    );
    let dag = run_dag(cfg, &[&stage], ExecMode::from_config(cfg), registry)?;
    let report = stage.report(&dag.stages[0], dag.sim_seconds, dag.wall_seconds)?;
    let (labels, objects, _mstats) = stage.output()?;
    Ok((report, labels, objects))
}
