//! The job-DAG runtime: one generic executor for every job shape.
//!
//! Where the coordinator used to carry four bespoke driver loops (fused
//! extraction, pair registration, canvas-tile compositing, band-tile
//! labeling), it now carries ONE: a job is a list of [`DagStage`]s, each
//! of which *plans* a set of work units (with explicit upstream inputs),
//! *runs* one unit per task attempt, *merges* each winning attempt and
//! *finalizes* once every unit has merged.  [`run_dag`] drains the whole
//! DAG over the shared worker-slot pool — one [`Scheduler`] spanning all
//! stages, so locality, bounded retries and straggler speculation behave
//! identically for every stage of every job.
//!
//! Two execution modes ([`ExecMode`]):
//!
//! * **Pipelined** (default) — a work unit is released to the slot pool
//!   the moment its *own* upstream units have merged (unit-level input
//!   satisfaction).  Downstream stages start while upstream stages still
//!   run: a registration pair matches as soon as its two scenes'
//!   feature files exist, a label band thresholds as soon as the canvas
//!   tiles covering its rows are composited.  One MapReduce startup is
//!   charged for the whole DAG.
//! * **Barrier** — the pre-DAG behavior: a stage's units are released
//!   only when every upstream stage has fully completed, and each stage
//!   is charged its own job startup, exactly as the four chained
//!   bulk-synchronous jobs used to be.
//!
//! The two modes must be **bit-identical** in their outputs: every unit
//! computes a pure function of its declared inputs, so release order can
//! only change *when* things run, never *what* they produce
//! (`rust/tests/dag_runtime.rs` holds this over random DAG topologies
//! with injected retries and speculation).
//!
//! Virtual time is event-driven: a slot's clock advances by each
//! attempt's `task_overhead + modeled_io + measured_compute`, but a unit
//! cannot *start* (on the virtual timeline) before its inputs were
//! satisfied, so
//!
//! ```text
//! completion(unit) = max(slot_clock, ready(unit)) + virtual(unit)
//! sim_seconds      = max over units/slots of completion
//! ```
//!
//! which makes the pipelined mode's consolidation of startups and
//! elimination of stage barriers directly visible in `sim_seconds`
//! (`difet bench` writes both modes into `BENCH_8.json`; CI gates on
//! them).
//!
//! Unit deps may also point at *earlier units of the same stage*
//! (`dep.unit < unit`, validated at plan time): that is how tree-shaped
//! merge stages express parent→children edges.  Intra-stage deps release
//! exactly like cross-stage ones in pipelined mode; in barrier mode the
//! whole-stage release frees the leaves and internal units cascade as
//! their children merge (own stage is never part of the barrier set).
//!
//! Observability: the executor registers, per DAG run,
//!
//! * `dag_queue_depth_max_<stage>` — gauge: peak released-but-unmerged
//!   units of that stage;
//! * `dag_stage_overlap_max` — gauge: peak number of stages that had
//!   released-but-unmerged units *simultaneously* (1 in barrier mode by
//!   construction, ≥ 2 whenever pipelining actually overlapped stages);
//! * `dag_eager_units` — counter: units released while one of their
//!   upstream stages still had unfinished units (each is a concrete
//!   instance of cross-stage pipelining).

use std::any::Any;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::analysis::dag_check;
use crate::analysis::hb::HbChecker;
use crate::cluster::CostModel;
use crate::config::Config;
use crate::dfs::NodeId;
use crate::metrics::Registry;
use crate::trace::critical::{critical_path, CriticalPath};
use crate::trace::{
    perfetto, AttemptEvent, AttemptOutcome, TraceEvent, TraceLog, TraceSink, UnitKind, UnitMeta,
};
use crate::util::{DifetError, Result, Stopwatch};

use super::scheduler::{monotonic_clock, Assignment, Scheduler, TaskHandle, WorkItem};

/// How the executor sequences stages: see the module docs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// Unit-level input satisfaction; one job startup for the whole DAG.
    Pipelined,
    /// Whole-stage barriers; one job startup per stage (the pre-DAG
    /// behavior of the four chained bulk-synchronous jobs).
    Barrier,
}

impl ExecMode {
    /// The mode the configuration asks for (`scheduler.barrier` /
    /// `difet --barrier`).
    pub fn from_config(cfg: &Config) -> ExecMode {
        if cfg.scheduler.barrier {
            ExecMode::Barrier
        } else {
            ExecMode::Pipelined
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ExecMode::Pipelined => "pipelined",
            ExecMode::Barrier => "barrier",
        }
    }
}

/// Reference to one unit of one stage (stage index within the DAG).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct UnitRef {
    pub stage: usize,
    pub unit: usize,
}

/// When a stage may *plan* (generate its unit set).
#[derive(Debug, Clone, Copy)]
pub enum Gate {
    /// As soon as the upstream stage has planned — used by stages whose
    /// units reference upstream units directly (the unit-level deps then
    /// control when each unit actually runs).
    Planned(usize),
    /// Only after the upstream stage fully completed and finalized —
    /// used when planning itself consumes the upstream *reduction* (the
    /// mosaic layout needs the solved alignment).
    Completed(usize),
}

impl Gate {
    fn target(&self) -> usize {
        match *self {
            Gate::Planned(s) | Gate::Completed(s) => s,
        }
    }
}

/// One planned work unit: its upstream inputs and locality preference.
#[derive(Debug, Clone, Default)]
pub struct UnitSpec {
    /// Upstream units whose merged outputs this unit consumes.  All must
    /// belong to already-planned stages.
    pub deps: Vec<UnitRef>,
    /// Nodes where running this unit is data-local, best first.
    pub preferred_nodes: Vec<NodeId>,
}

/// A stage's plan: its unit set plus the driver-side setup I/O (e.g.
/// shuffling input files into DFS) charged serially when the stage opens
/// on the virtual timeline.
pub struct StagePlan {
    pub units: Vec<UnitSpec>,
    pub plan_io_secs: f64,
}

/// What a unit body hands back: an opaque payload for [`DagStage::merge`]
/// plus its virtual-time accounting.
pub struct UnitOutput {
    pub payload: Box<dyn Any + Send>,
    /// Measured compute nanoseconds (wall time inside the unit body).
    pub compute_ns: u64,
    /// Modeled I/O seconds (DFS reads/writes under the cost model).
    pub io_secs: f64,
}

/// One stage of a job DAG.  Implementations carry their own inputs
/// (config, DFS, specs) and outputs (interior-mutable sinks the caller
/// reads back after [`run_dag`] returns).
///
/// Contract: `run_unit` must be a pure function of the stage inputs and
/// the merged outputs of the unit's declared `deps` — never of which
/// node/slot/attempt runs it or of the release order — so pipelined and
/// barrier schedules produce bit-identical results.  `merge` is called
/// exactly once per unit (only for the winning attempt) and `finalize`
/// exactly once, after every unit has merged.
///
/// # Example
///
/// A two-stage DAG: `nums` emits three numbers into a shared sink, and
/// `total` declares one unit per number (unit-level deps, so each is
/// released the moment *its* number merged) and folds them:
///
/// ```
/// use std::any::Any;
/// use std::sync::{Arc, Mutex};
/// use difet::config::Config;
/// use difet::coordinator::{
///     run_dag, DagStage, ExecMode, Gate, StagePlan, TaskHandle, UnitOutput, UnitRef, UnitSpec,
/// };
/// use difet::dfs::NodeId;
/// use difet::metrics::Registry;
///
/// struct Nums {
///     out: Arc<Mutex<Vec<u64>>>,
/// }
/// impl DagStage for Nums {
///     fn name(&self) -> &'static str {
///         "nums"
///     }
///     fn plan(&self) -> difet::Result<StagePlan> {
///         Ok(StagePlan { units: vec![UnitSpec::default(); 3], plan_io_secs: 0.0 })
///     }
///     fn run_unit(
///         &self,
///         unit: usize,
///         _handle: &TaskHandle,
///         _node: NodeId,
///     ) -> difet::Result<Option<UnitOutput>> {
///         Ok(Some(UnitOutput {
///             payload: Box::new(unit as u64 + 1),
///             compute_ns: 1_000,
///             io_secs: 0.0,
///         }))
///     }
///     fn merge(&self, unit: usize, payload: Box<dyn Any + Send>) -> difet::Result<()> {
///         let v = *payload.downcast::<u64>().expect("u64 payload");
///         let mut out = self.out.lock().unwrap();
///         if out.len() <= unit {
///             out.resize(unit + 1, 0);
///         }
///         out[unit] = v;
///         Ok(())
///     }
/// }
///
/// struct Total {
///     nums: Arc<Mutex<Vec<u64>>>,
///     total: Mutex<u64>,
/// }
/// impl DagStage for Total {
///     fn name(&self) -> &'static str {
///         "total"
///     }
///     fn gates(&self) -> Vec<Gate> {
///         vec![Gate::Planned(0)] // plan as soon as `nums` has planned
///     }
///     fn plan(&self) -> difet::Result<StagePlan> {
///         let units = (0..3)
///             .map(|u| UnitSpec {
///                 deps: vec![UnitRef { stage: 0, unit: u }],
///                 ..Default::default()
///             })
///             .collect();
///         Ok(StagePlan { units, plan_io_secs: 0.0 })
///     }
///     fn run_unit(
///         &self,
///         unit: usize,
///         _handle: &TaskHandle,
///         _node: NodeId,
///     ) -> difet::Result<Option<UnitOutput>> {
///         // The declared dep guarantees entry `unit` merged before
///         // this attempt was released.
///         let v = self.nums.lock().unwrap()[unit];
///         Ok(Some(UnitOutput { payload: Box::new(v * 10), compute_ns: 1_000, io_secs: 0.0 }))
///     }
///     fn merge(&self, _unit: usize, payload: Box<dyn Any + Send>) -> difet::Result<()> {
///         *self.total.lock().unwrap() += *payload.downcast::<u64>().expect("u64 payload");
///         Ok(())
///     }
/// }
///
/// let shared = Arc::new(Mutex::new(Vec::new()));
/// let nums = Nums { out: shared.clone() };
/// let total = Total { nums: shared, total: Mutex::new(0) };
/// let stages: Vec<&dyn DagStage> = vec![&nums, &total];
/// let report = run_dag(&Config::new(), &stages, ExecMode::Pipelined, &Registry::new())?;
/// assert_eq!(*total.total.lock().unwrap(), 60); // (1 + 2 + 3) × 10
/// assert_eq!(report.stages.len(), 2);
/// # Ok::<(), difet::DifetError>(())
/// ```
pub trait DagStage: Sync {
    /// Short stable name (metrics suffix + report rows).
    fn name(&self) -> &'static str;

    /// Planning prerequisites; the default is an unconditional plan at
    /// DAG start.
    fn gates(&self) -> Vec<Gate> {
        Vec::new()
    }

    /// Generate the unit set (called once, after the gates are met).
    fn plan(&self) -> Result<StagePlan>;

    /// Run one unit.  `Ok(None)` means the attempt observed cancellation
    /// (a losing speculative twin) and died cooperatively.
    fn run_unit(&self, unit: usize, handle: &TaskHandle, node: NodeId)
        -> Result<Option<UnitOutput>>;

    /// Merge the winning attempt's payload into the stage sink.
    fn merge(&self, unit: usize, payload: Box<dyn Any + Send>) -> Result<()>;

    /// Reduce after every unit merged (e.g. the label union-find merge).
    fn finalize(&self) -> Result<()> {
        Ok(())
    }

    /// What unit `unit` *is* for trace/critical-path attribution.
    /// Stages with non-compute units (ingest, tree merges) override.
    fn unit_kind(&self, _unit: usize) -> UnitKind {
        UnitKind::Compute
    }
}

/// Per-stage slice of a [`DagReport`].
#[derive(Debug, Clone)]
pub struct StageReport {
    pub name: &'static str,
    pub units: usize,
    /// Virtual time the stage opened (its first unit became runnable).
    pub open_secs: f64,
    /// Virtual time its last unit completed.
    pub close_secs: f64,
    /// Σ measured compute over all attempts of this stage's units.
    pub compute_seconds: f64,
    /// Σ modeled I/O over all attempts.  Plan-time shuffle I/O is NOT
    /// included (it shifts the stage's `open_secs` on the virtual
    /// timeline instead), matching the old per-job reports.
    pub io_seconds: f64,
    pub data_local_tasks: u64,
    pub rack_remote_tasks: u64,
    pub retries: u64,
    pub speculative_launches: u64,
    /// Units released while an upstream stage still had unmerged units —
    /// concrete cross-stage pipelining events (0 in barrier mode).
    pub eager_units: u64,
    /// Peak released-but-unmerged units (the queue-depth gauge value).
    pub max_queue_depth: u64,
    /// Virtual slot-busy seconds per node inside this stage (every
    /// completed attempt, winners and losing twins alike).
    pub node_busy_secs: Vec<f64>,
    /// Host wall-clock seconds spent inside `run_unit` across all
    /// attempts of this stage — the real-time twin of the virtual-time
    /// columns, so sim-time attribution and wall-time cost line up in
    /// one table (see `crate::profile`).
    pub real_seconds: f64,
}

impl StageReport {
    /// Busy span of the stage on the shared virtual timeline.
    pub fn span_secs(&self) -> f64 {
        (self.close_secs - self.open_secs).max(0.0)
    }

    /// The Hadoop-style counters every per-job report used to expose.
    pub fn scheduler_counters(&self) -> BTreeMap<String, u64> {
        let mut counters = BTreeMap::new();
        counters.insert("data_local_tasks".into(), self.data_local_tasks);
        counters.insert("rack_remote_tasks".into(), self.rack_remote_tasks);
        counters.insert("retries".into(), self.retries);
        counters.insert("speculative_launches".into(), self.speculative_launches);
        counters.insert("eager_units".into(), self.eager_units);
        counters
    }
}

/// Whole-DAG result: the one simulated clock all stages shared.
#[derive(Debug, Clone)]
pub struct DagReport {
    pub mode: ExecMode,
    /// Simulated time for the whole DAG (startup(s) + virtual span).
    pub sim_seconds: f64,
    /// Host wall-clock actually spent (diagnostics only).
    pub wall_seconds: f64,
    /// Peak number of stages with released-but-unmerged units at once.
    pub max_stage_overlap: u64,
    /// Worker slots per node (the utilization denominator).
    pub slots_per_node: usize,
    pub stages: Vec<StageReport>,
    /// The sealed virtual-time event log (tracing enabled only).
    pub trace: Option<Arc<TraceLog>>,
    /// Critical-path attribution of `sim_seconds` (tracing enabled only).
    pub critical_path: Option<CriticalPath>,
}

impl DagReport {
    pub fn stage(&self, name: &str) -> Option<&StageReport> {
        self.stages.iter().find(|s| s.name == name)
    }

    /// Fraction of node `node`'s slot-seconds spent busy during `stage`'s
    /// span on the virtual timeline (0 for empty spans; idle fraction is
    /// the complement).
    pub fn node_utilization(&self, stage: usize, node: usize) -> f64 {
        let s = &self.stages[stage];
        let capacity = s.span_secs() * self.slots_per_node.max(1) as f64;
        if capacity <= 0.0 {
            return 0.0;
        }
        let busy = s.node_busy_secs.get(node).copied().unwrap_or(0.0);
        (busy / capacity).clamp(0.0, 1.0)
    }
}

// ---------------------------------------------------------------------------
// Executor internals.
// ---------------------------------------------------------------------------

/// The scheduler work item: one (stage, unit) pair.
#[derive(Clone)]
struct DagTask {
    unit: UnitRef,
    preferred: Vec<NodeId>,
}

impl WorkItem for DagTask {
    fn preferred_nodes(&self) -> &[NodeId] {
        &self.preferred
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum StageStatus {
    /// Gates not met yet.
    Blocked,
    /// A thread is running `plan()` right now.
    Planning,
    /// Units exist; some not merged yet.
    Running,
    /// A thread is running `finalize()` right now.
    Finalizing,
    /// Everything merged and finalized.
    Done,
}

struct UnitState {
    deps_remaining: usize,
    /// Distinct upstream stages this unit depends on (eager detection).
    dep_stages: Vec<usize>,
    /// Downstream units waiting on this one.
    dependents: Vec<UnitRef>,
    preferred: Vec<NodeId>,
    released: bool,
    merged: bool,
    /// Virtual time the unit became runnable (valid once released).
    ready_ns: u64,
    /// Virtual completion time (valid once merged).
    completion_ns: u64,
}

struct StageState {
    status: StageStatus,
    units: Vec<UnitState>,
    outstanding: usize,
    /// All upstream stages (gates ∪ unit-dep stages) — barrier release set.
    upstream: Vec<usize>,
    /// Barrier mode: whether the whole-stage release already happened.
    released_all: bool,
    plan_io_ns: u64,
    open_ns: u64,
    close_ns: u64,
    compute_ns: u64,
    io_ns: u64,
    data_local: u64,
    rack_remote: u64,
    retries: u64,
    spec_launches: u64,
    eager: u64,
    depth: u64,
    max_depth: u64,
    /// Virtual slot-busy ns per node charged to this stage.
    node_busy_ns: Vec<u64>,
    /// Host wall-clock ns spent inside `run_unit` for this stage.
    real_ns: u64,
    /// Whether a `StageOpen` trace event was emitted for this stage.
    trace_opened: bool,
}

impl StageState {
    fn new(nodes: usize) -> Self {
        StageState {
            status: StageStatus::Blocked,
            units: Vec::new(),
            outstanding: 0,
            upstream: Vec::new(),
            released_all: false,
            plan_io_ns: 0,
            open_ns: 0,
            close_ns: 0,
            compute_ns: 0,
            io_ns: 0,
            data_local: 0,
            rack_remote: 0,
            retries: 0,
            spec_launches: 0,
            eager: 0,
            depth: 0,
            max_depth: 0,
            node_busy_ns: vec![0; nodes],
            real_ns: 0,
            trace_opened: false,
        }
    }

    fn planned(&self) -> bool {
        matches!(
            self.status,
            StageStatus::Running | StageStatus::Finalizing | StageStatus::Done
        )
    }
}

struct DagState {
    stages: Vec<StageState>,
    /// Stages with depth > 0 right now (overlap metric).
    live_stages: u64,
    max_overlap: u64,
    done_stages: usize,
}

enum Act {
    Plan(usize),
    Finalize(usize),
}

struct DagExec<'a> {
    stages: &'a [&'a dyn DagStage],
    sched: Scheduler<DagTask>,
    state: Mutex<DagState>,
    mode: ExecMode,
    startup_ns: u64,
    overhead_ns: u64,
    /// Max over slots of each slot's final virtual clock (losing twins
    /// keep their slot busy even though they merge nothing).
    max_slot_ns: AtomicU64,
    /// Cluster size, for plan-time locality-hint validation.
    nodes: usize,
    slots_per_node: usize,
    /// Deterministic trace collector (`scheduler.trace` / `--trace`).
    /// Same lock discipline as `hb`: its own mutex, never takes `state`,
    /// and the per-attempt hot path only appends to a slot-local buffer.
    trace: Option<TraceSink>,
    /// Audit-mode happens-before checker (`scheduler.audit`, default on):
    /// the executor reports release/attempt/merge events and the run
    /// fails if any history violated the merge-before-observe order.
    /// Lock order: the checker has its own mutex and never takes
    /// `state`, so reporting while holding `state` cannot deadlock.
    hb: Option<HbChecker>,
}

impl<'a> DagExec<'a> {
    /// Are this stage's gates met?  (`Planned` ⇒ upstream planned,
    /// `Completed` ⇒ upstream done — identical in both modes; the modes
    /// differ in unit *release*, not in planning.)
    fn gates_met(&self, st: &DagState, gates: &[Gate]) -> bool {
        gates.iter().all(|g| match *g {
            Gate::Planned(p) => p < st.stages.len() && st.stages[p].planned(),
            Gate::Completed(p) => {
                p < st.stages.len() && st.stages[p].status == StageStatus::Done
            }
        })
    }

    /// One state-machine step under the lock; transitional statuses stop
    /// two threads from planning/finalizing the same stage twice.
    fn next_act(&self, st: &mut DagState) -> Option<Act> {
        if let Some(i) = st
            .stages
            .iter()
            .position(|s| s.status == StageStatus::Running && s.outstanding == 0)
        {
            st.stages[i].status = StageStatus::Finalizing;
            return Some(Act::Finalize(i));
        }
        let blocked: Vec<usize> = st
            .stages
            .iter()
            .enumerate()
            .filter(|(_, s)| s.status == StageStatus::Blocked)
            .map(|(i, _)| i)
            .collect();
        for i in blocked {
            if self.gates_met(st, &self.stages[i].gates()) {
                st.stages[i].status = StageStatus::Planning;
                return Some(Act::Plan(i));
            }
        }
        None
    }

    /// Drive planning/finalization until nothing more can happen now.
    /// Returns an error for a structurally stalled DAG (gate cycle).
    fn advance(&self) -> Result<()> {
        loop {
            let act = {
                let mut st = self.state.lock().unwrap();
                match self.next_act(&mut st) {
                    Some(act) => act,
                    None => {
                        let idle = st
                            .stages
                            .iter()
                            .all(|s| matches!(s.status, StageStatus::Blocked | StageStatus::Done));
                        if idle && st.done_stages < st.stages.len() {
                            let stuck: Vec<&str> = st
                                .stages
                                .iter()
                                .enumerate()
                                .filter(|(_, s)| s.status == StageStatus::Blocked)
                                .map(|(i, _)| self.stages[i].name())
                                .collect();
                            return Err(DifetError::Job(format!(
                                "job DAG stalled: stage gates never satisfiable for {stuck:?}"
                            )));
                        }
                        return Ok(());
                    }
                }
            };
            match act {
                Act::Plan(i) => {
                    let plan = self.stages[i].plan()?;
                    let mut st = self.state.lock().unwrap();
                    self.install_plan(&mut st, i, plan)?;
                }
                Act::Finalize(i) => {
                    self.stages[i].finalize()?;
                    let mut st = self.state.lock().unwrap();
                    st.stages[i].status = StageStatus::Done;
                    st.done_stages += 1;
                    if let Some(tr) = &self.trace {
                        let s = &mut st.stages[i];
                        if !s.trace_opened {
                            // A zero-unit stage can finalize before its
                            // barrier release ever opens it; give it a
                            // zero-width span so the log stays one
                            // open / one finalize per stage.
                            s.trace_opened = true;
                            tr.emit(TraceEvent::StageOpen {
                                stage: i,
                                open_ns: s.close_ns,
                                base_ns: s.close_ns,
                                startup_ns: 0,
                                plan_io_ns: 0,
                            });
                        }
                        tr.emit(TraceEvent::StageFinalize { stage: i, close_ns: s.close_ns });
                    }
                    if st.done_stages == st.stages.len() {
                        self.sched.close();
                    } else if self.mode == ExecMode::Barrier {
                        self.release_barrier_ready(&mut st);
                    }
                }
            }
        }
    }

    /// Validate and install a freshly planned stage, releasing whatever
    /// units are already runnable.
    fn install_plan(&self, st: &mut DagState, stage: usize, plan: StagePlan) -> Result<()> {
        // Layer-2 audit: reject a malformed plan before any unit state
        // exists, with every issue named (not just the first).
        let unit_defs: Vec<dag_check::UnitDef> = plan
            .units
            .iter()
            .map(|spec| dag_check::UnitDef {
                deps: spec.deps.iter().map(|d| (d.stage, d.unit)).collect(),
                preferred: spec.preferred_nodes.iter().map(|n| n.0).collect(),
            })
            .collect();
        let planned_units: Vec<Option<usize>> = st
            .stages
            .iter()
            .enumerate()
            .map(|(s, up)| (s != stage && up.planned()).then(|| up.units.len()))
            .collect();
        let issues = dag_check::validate_plan(
            self.stages[stage].name(),
            stage,
            &unit_defs,
            &planned_units,
            self.nodes,
        );
        if !issues.is_empty() {
            return Err(DifetError::Job(issues.join("; ")));
        }
        if let Some(hb) = &self.hb {
            for (u, spec) in plan.units.iter().enumerate() {
                let deps: Vec<(usize, usize)> =
                    spec.deps.iter().map(|d| (d.stage, d.unit)).collect();
                hb.register_unit((stage, u), &deps);
            }
        }
        if let Some(tr) = &self.trace {
            let metas: Vec<UnitMeta> = plan
                .units
                .iter()
                .enumerate()
                .map(|(u, spec)| UnitMeta {
                    deps: spec.deps.iter().map(|d| (d.stage, d.unit)).collect(),
                    kind: self.stages[stage].unit_kind(u),
                })
                .collect();
            tr.register_stage(stage, self.stages[stage].name(), metas);
        }
        // Resolve deps (immutable reads across stages); the validator
        // above guarantees every reference is in range and planned.
        let mut units = Vec::with_capacity(plan.units.len());
        let mut upstream: Vec<usize> = self.stages[stage]
            .gates()
            .iter()
            .map(|g| g.target())
            .collect();
        for spec in &plan.units {
            let mut deps_remaining = 0usize;
            let mut dep_stages: Vec<usize> = Vec::new();
            let mut ready_ns = 0u64;
            for d in &spec.deps {
                if d.stage == stage {
                    // Intra-stage dep (a tree-merge parent on its
                    // children, validated `d.unit < u`): the child is in
                    // this very plan, so it cannot have merged yet.  Own
                    // stage stays out of `dep_stages` (internal nodes are
                    // not cross-stage-eager) and out of `upstream` (a
                    // stage barriering on itself would never release).
                    deps_remaining += 1;
                    continue;
                }
                let dep_unit = &st.stages[d.stage].units[d.unit];
                if dep_unit.merged {
                    ready_ns = ready_ns.max(dep_unit.completion_ns);
                } else {
                    deps_remaining += 1;
                }
                if !dep_stages.contains(&d.stage) {
                    dep_stages.push(d.stage);
                }
                if !upstream.contains(&d.stage) {
                    upstream.push(d.stage);
                }
            }
            units.push(UnitState {
                deps_remaining,
                dep_stages,
                dependents: Vec::new(),
                preferred: spec.preferred_nodes.clone(),
                released: false,
                merged: false,
                ready_ns,
                completion_ns: 0,
            });
        }
        // Register dependents on the upstream units (second pass, now that
        // validation cannot fail halfway).  Own-stage deps register on the
        // local `units` vec — those units are not installed yet.
        for (u, spec) in plan.units.iter().enumerate() {
            for d in &spec.deps {
                if d.stage == stage {
                    units[d.unit].dependents.push(UnitRef { stage, unit: u });
                } else if !st.stages[d.stage].units[d.unit].merged {
                    st.stages[d.stage].units[d.unit]
                        .dependents
                        .push(UnitRef { stage, unit: u });
                }
            }
        }

        let s = &mut st.stages[stage];
        s.plan_io_ns = secs_to_ns(plan.plan_io_secs);
        s.outstanding = units.len();
        s.units = units;
        s.upstream = upstream;
        s.status = StageStatus::Running;

        match self.mode {
            ExecMode::Pipelined => {
                // Open now: gates are met, so the gate times are known.
                // `base` is the latest gate time; the DAG-wide startup is
                // only charged where it actually extends past the gates
                // (`max(startup, base) == base + startup.saturating_sub(base)`),
                // which is exactly the slice the trace attributes to it.
                let mut base = 0u64;
                for g in self.stages[stage].gates() {
                    base = base.max(match g {
                        Gate::Planned(p) => st.stages[p].open_ns,
                        Gate::Completed(p) => st.stages[p].close_ns,
                    });
                }
                let startup_charged = self.startup_ns.saturating_sub(base);
                let open = base + startup_charged + st.stages[stage].plan_io_ns;
                st.stages[stage].open_ns = open;
                st.stages[stage].close_ns = open;
                if let Some(tr) = &self.trace {
                    st.stages[stage].trace_opened = true;
                    tr.emit(TraceEvent::StageOpen {
                        stage,
                        open_ns: open,
                        base_ns: base,
                        startup_ns: startup_charged,
                        plan_io_ns: st.stages[stage].plan_io_ns,
                    });
                }
                let ready: Vec<usize> = st.stages[stage]
                    .units
                    .iter()
                    .enumerate()
                    .filter(|(_, u)| u.deps_remaining == 0)
                    .map(|(u, _)| u)
                    .collect();
                for unit in ready {
                    self.release_unit(st, UnitRef { stage, unit });
                }
            }
            ExecMode::Barrier => self.release_barrier_ready(st),
        }
        Ok(())
    }

    /// Barrier mode: release every unit of each planned stage whose
    /// upstream stages have ALL completed (the whole-stage barrier), with
    /// a fresh per-stage job startup on the virtual clock.
    fn release_barrier_ready(&self, st: &mut DagState) {
        for stage in 0..st.stages.len() {
            let s = &st.stages[stage];
            if s.status != StageStatus::Running || s.released_all {
                continue;
            }
            let upstream_done = s
                .upstream
                .iter()
                .all(|&p| st.stages[p].status == StageStatus::Done);
            if !upstream_done {
                continue;
            }
            let mut base = 0u64;
            for &p in &st.stages[stage].upstream {
                base = base.max(st.stages[p].close_ns);
            }
            let open = base + self.startup_ns + st.stages[stage].plan_io_ns;
            st.stages[stage].released_all = true;
            st.stages[stage].open_ns = open;
            st.stages[stage].close_ns = open;
            if let Some(tr) = &self.trace {
                st.stages[stage].trace_opened = true;
                tr.emit(TraceEvent::StageOpen {
                    stage,
                    open_ns: open,
                    base_ns: base,
                    startup_ns: self.startup_ns,
                    plan_io_ns: st.stages[stage].plan_io_ns,
                });
            }
            let n_units = st.stages[stage].units.len();
            for unit in 0..n_units {
                // With all upstream stages Done, only *intra-stage* deps
                // (tree-merge parents on their children) can still be
                // pending; those units release from `complete_unit` as
                // their children merge.
                st.stages[stage].units[unit].ready_ns = open;
                if st.stages[stage].units[unit].deps_remaining == 0 {
                    self.release_unit(st, UnitRef { stage, unit });
                }
            }
        }
    }

    /// Hand one runnable unit to the scheduler and keep the queue-depth /
    /// overlap / eager metrics.
    fn release_unit(&self, st: &mut DagState, r: UnitRef) {
        {
            let s = &mut st.stages[r.stage];
            let u = &mut s.units[r.unit];
            debug_assert!(!u.released && u.deps_remaining == 0);
            u.released = true;
            u.ready_ns = u.ready_ns.max(s.open_ns);
            if s.depth == 0 {
                st.live_stages += 1;
            }
        }
        st.max_overlap = st.max_overlap.max(st.live_stages);
        let s = &mut st.stages[r.stage];
        s.depth += 1;
        s.max_depth = s.max_depth.max(s.depth);
        // Pipelining observability: this release happened while one of
        // the unit's input stages still had unfinished units.
        let eager = st.stages[r.stage].units[r.unit]
            .dep_stages
            .iter()
            .any(|&p| st.stages[p].outstanding > 0);
        if eager {
            st.stages[r.stage].eager += 1;
        }
        let preferred = st.stages[r.stage].units[r.unit].preferred.clone();
        // Record the release before the scheduler can hand the unit to a
        // slot, so an attempt can never be observed before its release.
        if let Some(hb) = &self.hb {
            hb.on_release((r.stage, r.unit));
        }
        if let Some(tr) = &self.trace {
            tr.emit(TraceEvent::Release {
                stage: r.stage,
                unit: r.unit,
                at_ns: st.stages[r.stage].units[r.unit].ready_ns,
                eager,
            });
        }
        self.sched.push(DagTask { unit: r, preferred });
    }

    /// Record a winning merge: virtual completion, dependent releases.
    fn complete_unit(&self, r: UnitRef, completion_ns: u64) {
        let mut st = self.state.lock().unwrap();
        let s = &mut st.stages[r.stage];
        let dependents = {
            let u = &mut s.units[r.unit];
            debug_assert!(!u.merged);
            u.merged = true;
            u.completion_ns = completion_ns;
            std::mem::take(&mut u.dependents)
        };
        s.outstanding -= 1;
        s.close_ns = s.close_ns.max(completion_ns);
        s.depth -= 1;
        if s.depth == 0 {
            st.live_stages -= 1;
        }
        for d in dependents {
            let du = &mut st.stages[d.stage].units[d.unit];
            du.ready_ns = du.ready_ns.max(completion_ns);
            du.deps_remaining -= 1;
            // Barrier mode releases intra-stage dependents too, once the
            // whole-stage release has happened (the stage's cross-stage
            // barrier was already paid; tree-internal units then cascade).
            if du.deps_remaining == 0
                && (self.mode == ExecMode::Pipelined || st.stages[d.stage].released_all)
            {
                self.release_unit(&mut st, d);
            }
        }
    }

    /// The worker-slot body: identical lifecycle to the old per-job
    /// drivers, but spanning every stage of the DAG.  Trace events are
    /// buffered slot-locally and flushed once at slot exit, so tracing
    /// adds no lock to the per-attempt hot path.
    fn slot_loop(&self, node: NodeId, slot: usize) {
        let mut clock_ns = 0u64;
        let mut tbuf: Vec<TraceEvent> = Vec::new();
        loop {
            let (task, handle) = match self.sched.next_assignment(node) {
                Assignment::Done => break,
                Assignment::Run(task, handle) => (task, handle),
            };
            let UnitRef { stage, unit } = task.unit;
            // Every attempt — first, retry or speculative twin — is about
            // to observe its deps' merged outputs: assert they merged.
            if let Some(hb) = &self.hb {
                hb.on_attempt_start((stage, unit), handle.launch_seq, handle.speculative);
            }
            // Per-attempt counters + the unit's ready time (stable once
            // released — nothing mutates it after the scheduler push).
            let ready_ns = {
                let mut st = self.state.lock().unwrap();
                let s = &mut st.stages[stage];
                if handle.speculative {
                    s.spec_launches += 1;
                } else if task.preferred.contains(&node) {
                    s.data_local += 1;
                } else {
                    s.rack_remote += 1;
                }
                s.units[unit].ready_ns
            };
            let attempt_event = |begin: u64, end: u64, io: u64, compute: u64, ovh: u64, outcome| {
                TraceEvent::Attempt(AttemptEvent {
                    stage,
                    unit,
                    attempt: handle.attempt,
                    launch_seq: handle.launch_seq,
                    speculative: handle.speculative,
                    node: node.0,
                    slot,
                    begin_ns: begin,
                    end_ns: end,
                    overhead_ns: ovh,
                    io_ns: io,
                    compute_ns: compute,
                    outcome,
                })
            };
            // Real-time accounting around the actual compute: one
            // monotonic read on each side (always on — `wall_seconds`
            // is measured unconditionally too) plus a profiler span
            // named after the stage so kernel spans nest under it.
            let unit_result = {
                let real_t0 = crate::profile::clock_ns();
                let span = crate::profile::enter(self.stages[stage].name());
                let unit_result = self.stages[stage].run_unit(unit, &handle, node);
                drop(span);
                let real_ns = crate::profile::clock_ns().saturating_sub(real_t0);
                self.state.lock().unwrap().stages[stage].real_ns += real_ns;
                unit_result
            };
            match unit_result {
                Ok(Some(out)) => {
                    let io_ns = secs_to_ns(out.io_secs);
                    let virtual_ns = self.overhead_ns + io_ns + out.compute_ns;
                    // Busy-slot accounting happens for every completed
                    // attempt, winners and losing twins alike (the slot
                    // really was occupied).
                    {
                        let mut st = self.state.lock().unwrap();
                        let s = &mut st.stages[stage];
                        s.compute_ns += out.compute_ns;
                        s.io_ns += io_ns;
                        s.node_busy_ns[node.0] += virtual_ns;
                    }
                    let begin = clock_ns.max(ready_ns);
                    let completion = begin + virtual_ns;
                    clock_ns = completion;
                    let won = self.sched.report_success(&handle);
                    if self.trace.is_some() {
                        let outcome =
                            if won { AttemptOutcome::Won } else { AttemptOutcome::Lost };
                        tbuf.push(attempt_event(
                            begin,
                            completion,
                            io_ns,
                            out.compute_ns,
                            self.overhead_ns,
                            outcome,
                        ));
                    }
                    if won {
                        let merged = self.stages[stage].merge(unit, out.payload);
                        match merged {
                            Ok(()) => {
                                if let Some(hb) = &self.hb {
                                    hb.on_merge((stage, unit));
                                }
                                self.complete_unit(task.unit, completion);
                                if let Err(e) = self.advance() {
                                    self.sched.abort(e.to_string());
                                }
                            }
                            Err(e) => self.sched.abort(e.to_string()),
                        }
                    }
                }
                Ok(None) => {
                    // Cooperative kill: zero-width marker, no clock.
                    if self.trace.is_some() {
                        let at = clock_ns.max(ready_ns);
                        tbuf.push(attempt_event(at, at, 0, 0, 0, AttemptOutcome::Killed));
                    }
                    self.sched.report_cancelled(&handle);
                }
                Err(e) => {
                    if self.trace.is_some() {
                        let at = clock_ns.max(ready_ns);
                        tbuf.push(attempt_event(at, at, 0, 0, 0, AttemptOutcome::Failed));
                    }
                    if self.sched.report_failure(&handle, &e.to_string()) {
                        self.state.lock().unwrap().stages[stage].retries += 1;
                    }
                }
            }
        }
        self.max_slot_ns.fetch_max(clock_ns, Ordering::Relaxed);
        if let Some(tr) = &self.trace {
            tr.flush(&mut tbuf);
        }
    }

    fn report(&self, wall_seconds: f64, registry: &Registry) -> DagReport {
        let st = self.state.lock().unwrap();
        let mut stages = Vec::with_capacity(st.stages.len());
        let mut sim_ns = self.max_slot_ns.load(Ordering::Relaxed);
        for s in st.stages.iter() {
            sim_ns = sim_ns.max(s.close_ns);
        }
        for (i, s) in st.stages.iter().enumerate() {
            let name = self.stages[i].name();
            registry
                .gauge(&format!("dag_queue_depth_max_{name}"))
                .set(s.max_depth as f64);
            stages.push(StageReport {
                name,
                units: s.units.len(),
                open_secs: s.open_ns as f64 * 1e-9,
                close_secs: s.close_ns as f64 * 1e-9,
                compute_seconds: s.compute_ns as f64 * 1e-9,
                io_seconds: s.io_ns as f64 * 1e-9,
                data_local_tasks: s.data_local,
                rack_remote_tasks: s.rack_remote,
                retries: s.retries,
                speculative_launches: s.spec_launches,
                eager_units: s.eager,
                max_queue_depth: s.max_depth,
                node_busy_secs: s.node_busy_ns.iter().map(|&b| b as f64 * 1e-9).collect(),
                real_seconds: s.real_ns as f64 * 1e-9,
            });
        }
        registry.gauge("dag_stage_overlap_max").set(st.max_overlap as f64);
        registry
            .counter("dag_eager_units")
            .add(st.stages.iter().map(|s| s.eager).sum());
        if crate::profile::is_enabled() {
            crate::profile::snapshot().export_gauges(registry);
        }
        let max_stage_overlap = st.max_overlap;
        drop(st);
        let (trace_log, cp) = match &self.trace {
            Some(tr) => {
                let log = tr.seal(self.mode.name(), self.nodes, self.slots_per_node, sim_ns);
                let cp = critical_path(&log);
                for (cat, _) in cp.breakdown() {
                    registry
                        .gauge(&format!("critical_path_seconds_{}", cat.name()))
                        .set(cp.seconds(cat));
                }
                (Some(Arc::new(log)), Some(cp))
            }
            None => (None, None),
        };
        DagReport {
            mode: self.mode,
            sim_seconds: sim_ns as f64 * 1e-9,
            wall_seconds,
            max_stage_overlap,
            slots_per_node: self.slots_per_node,
            stages,
            trace: trace_log,
            critical_path: cp,
        }
    }

    /// Seal the report and, when `--trace <path>` asked for it, write the
    /// Perfetto export (embedding the registry snapshot).  One invocation
    /// running several DAGs rewrites the file per DAG — last one wins.
    fn finish(&self, wall_seconds: f64, cfg: &Config, registry: &Registry) -> Result<DagReport> {
        let report = self.report(wall_seconds, registry);
        if let (Some(path), Some(log)) =
            (cfg.scheduler.trace_path.as_deref(), report.trace.as_deref())
        {
            perfetto::write_file(path, log, Some(&registry.snapshot()))?;
        }
        Ok(report)
    }
}

fn secs_to_ns(secs: f64) -> u64 {
    (secs.max(0.0) * 1e9) as u64
}

/// Run a job DAG on the simulated cluster: spawn `nodes × slots_per_node`
/// worker slots, drain every stage through one shared [`Scheduler`]
/// (locality / bounded retries / speculation for every stage), and
/// account virtual time per the module docs.
///
/// # Example
///
/// A single map-shaped stage whose units square their index (see
/// [`DagStage`] for a multi-stage DAG with unit-level deps and gates):
///
/// ```
/// use std::any::Any;
/// use std::sync::Mutex;
/// use difet::config::Config;
/// use difet::coordinator::{run_dag, DagStage, ExecMode, StagePlan, TaskHandle, UnitOutput, UnitSpec};
/// use difet::dfs::NodeId;
/// use difet::metrics::Registry;
///
/// struct Square {
///     sink: Mutex<Vec<u64>>,
/// }
/// impl DagStage for Square {
///     fn name(&self) -> &'static str {
///         "square"
///     }
///     fn plan(&self) -> difet::Result<StagePlan> {
///         Ok(StagePlan { units: vec![UnitSpec::default(); 4], plan_io_secs: 0.0 })
///     }
///     fn run_unit(
///         &self,
///         unit: usize,
///         _handle: &TaskHandle,
///         _node: NodeId,
///     ) -> difet::Result<Option<UnitOutput>> {
///         let sq = (unit as u64) * (unit as u64);
///         Ok(Some(UnitOutput { payload: Box::new(sq), compute_ns: 1_000, io_secs: 0.0 }))
///     }
///     fn merge(&self, _unit: usize, payload: Box<dyn Any + Send>) -> difet::Result<()> {
///         self.sink.lock().unwrap().push(*payload.downcast::<u64>().expect("u64 payload"));
///         Ok(())
///     }
/// }
///
/// let stage = Square { sink: Mutex::new(Vec::new()) };
/// let stages: Vec<&dyn DagStage> = vec![&stage];
/// let report = run_dag(&Config::new(), &stages, ExecMode::Pipelined, &Registry::new())?;
/// let mut got = stage.sink.into_inner().unwrap();
/// got.sort_unstable(); // merge order follows virtual-time completion
/// assert_eq!(got, vec![0, 1, 4, 9]);
/// assert!(report.sim_seconds > 0.0);
/// # Ok::<(), difet::DifetError>(())
/// ```
pub fn run_dag(
    cfg: &Config,
    stages: &[&dyn DagStage],
    mode: ExecMode,
    registry: &Registry,
) -> Result<DagReport> {
    let wall = Stopwatch::start();
    let cost = CostModel::new(&cfg.cluster);
    // Layer-2 pre-flight: a DAG whose gate graph can never finish is
    // rejected before a single worker slot spawns.
    let names: Vec<&str> = stages.iter().map(|s| s.name()).collect();
    let gate_defs: Vec<Vec<dag_check::GateDef>> = stages
        .iter()
        .map(|s| {
            s.gates()
                .iter()
                .map(|g| dag_check::GateDef {
                    kind: match g {
                        Gate::Planned(_) => dag_check::GateKind::Planned,
                        Gate::Completed(_) => dag_check::GateKind::Completed,
                    },
                    target: g.target(),
                })
                .collect()
        })
        .collect();
    let issues = dag_check::validate_gates(&names, &gate_defs);
    if !issues.is_empty() {
        return Err(DifetError::Job(issues.join("; ")));
    }
    let exec = DagExec {
        stages,
        sched: Scheduler::new_dynamic(&cfg.scheduler, monotonic_clock()),
        state: Mutex::new(DagState {
            stages: (0..stages.len())
                .map(|_| StageState::new(cfg.cluster.nodes))
                .collect(),
            live_stages: 0,
            max_overlap: 0,
            done_stages: 0,
        }),
        mode,
        startup_ns: secs_to_ns(cost.job_startup()),
        overhead_ns: secs_to_ns(cost.task_overhead()),
        max_slot_ns: AtomicU64::new(0),
        nodes: cfg.cluster.nodes,
        slots_per_node: cfg.cluster.slots_per_node,
        trace: cfg.scheduler.trace_enabled().then(|| TraceSink::new(stages.len())),
        hb: cfg.scheduler.audit.then(HbChecker::new),
    };
    if stages.is_empty() {
        exec.sched.close();
        return exec.finish(wall.elapsed_secs(), cfg, registry);
    }
    // Initial planning wave (and zero-unit stage finalization).
    exec.advance()?;
    std::thread::scope(|scope| {
        for node in 0..cfg.cluster.nodes {
            for slot in 0..cfg.cluster.slots_per_node {
                let exec = &exec;
                scope.spawn(move || exec.slot_loop(NodeId(node), slot));
            }
        }
    });
    if let Some(reason) = exec.sched.abort_reason() {
        return Err(DifetError::Job(reason));
    }
    // Layer-3 verdict: the sampled history must be race-free on every
    // attempt, including retries and losing speculative twins.
    if let Some(hb) = &exec.hb {
        match hb.finish() {
            Ok(checks) => registry.counter("audit_hb_checks").add(checks),
            Err(violations) => {
                return Err(DifetError::Job(format!(
                    "happens-before audit failed ({} violation(s)): {}",
                    violations.len(),
                    violations.join("; ")
                )))
            }
        }
    }
    exec.finish(wall.elapsed_secs(), cfg, registry)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    /// A synthetic stage: unit `u` computes a mix of its own id and its
    /// deps' merged values; results land in a shared map.
    struct MixStage {
        name: &'static str,
        index: usize,
        gates: Vec<Gate>,
        unit_deps: Vec<Vec<UnitRef>>,
        values: Mutex<BTreeMap<(usize, usize), u64>>,
        upstream_values: std::sync::Arc<Mutex<BTreeMap<(usize, usize), u64>>>,
        fail_first_attempt: bool,
        plan_io_secs: f64,
        finalized: AtomicU64,
    }

    fn mix(a: u64, b: u64) -> u64 {
        let mut z = a ^ b.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z ^ (z >> 31)
    }

    impl DagStage for MixStage {
        fn name(&self) -> &'static str {
            self.name
        }
        fn gates(&self) -> Vec<Gate> {
            self.gates.clone()
        }
        fn plan(&self) -> Result<StagePlan> {
            Ok(StagePlan {
                units: self
                    .unit_deps
                    .iter()
                    .map(|deps| UnitSpec { deps: deps.clone(), preferred_nodes: Vec::new() })
                    .collect(),
                plan_io_secs: self.plan_io_secs,
            })
        }
        fn run_unit(
            &self,
            unit: usize,
            handle: &TaskHandle,
            _node: NodeId,
        ) -> Result<Option<UnitOutput>> {
            if self.fail_first_attempt && handle.attempt == 0 {
                return Err(DifetError::Job(format!(
                    "injected failure (unit {unit}, attempt {})",
                    handle.attempt
                )));
            }
            let shared = self.upstream_values.lock().unwrap();
            let mut v = mix(self.index as u64, unit as u64);
            for d in &self.unit_deps[unit] {
                let dep = shared
                    .get(&(d.stage, d.unit))
                    .copied()
                    .expect("dep ran before its consumer");
                v = mix(v, dep);
            }
            drop(shared);
            Ok(Some(UnitOutput { payload: Box::new(v), compute_ns: 1_000, io_secs: 0.0 }))
        }
        fn merge(&self, unit: usize, payload: Box<dyn Any + Send>) -> Result<()> {
            let v = *payload.downcast::<u64>().expect("payload type");
            self.values.lock().unwrap().insert((self.index, unit), v);
            self.upstream_values
                .lock()
                .unwrap()
                .insert((self.index, unit), v);
            Ok(())
        }
        fn finalize(&self) -> Result<()> {
            self.finalized.fetch_add(1, Ordering::Relaxed);
            Ok(())
        }
    }

    fn mk_stage(
        shared: &std::sync::Arc<Mutex<BTreeMap<(usize, usize), u64>>>,
        name: &'static str,
        index: usize,
        gates: Vec<Gate>,
        unit_deps: Vec<Vec<UnitRef>>,
    ) -> MixStage {
        MixStage {
            name,
            index,
            gates,
            unit_deps,
            values: Mutex::new(BTreeMap::new()),
            upstream_values: shared.clone(),
            fail_first_attempt: false,
            plan_io_secs: 0.0,
            finalized: AtomicU64::new(0),
        }
    }

    fn test_cfg() -> Config {
        let mut cfg = Config::new();
        cfg.cluster.nodes = 2;
        cfg.cluster.slots_per_node = 2;
        cfg.cluster.job_startup = 1.0;
        cfg.cluster.task_overhead = 0.1;
        cfg
    }

    #[test]
    fn two_stage_chain_runs_and_finalizes_in_both_modes() {
        for mode in [ExecMode::Pipelined, ExecMode::Barrier] {
            let shared = std::sync::Arc::new(Mutex::new(BTreeMap::new()));
            let a = mk_stage(&shared, "a", 0, vec![], vec![vec![], vec![], vec![]]);
            let b = mk_stage(
                &shared,
                "b",
                1,
                vec![Gate::Planned(0)],
                vec![
                    vec![UnitRef { stage: 0, unit: 0 }, UnitRef { stage: 0, unit: 1 }],
                    vec![UnitRef { stage: 0, unit: 2 }],
                ],
            );
            let registry = Registry::new();
            let rep = run_dag(&test_cfg(), &[&a, &b], mode, &registry).expect("dag run");
            assert_eq!(rep.stages.len(), 2);
            assert_eq!(rep.stages[0].units, 3);
            assert_eq!(rep.stages[1].units, 2);
            assert_eq!(a.finalized.load(Ordering::Relaxed), 1);
            assert_eq!(b.finalized.load(Ordering::Relaxed), 1);
            assert_eq!(a.values.lock().unwrap().len(), 3);
            assert_eq!(b.values.lock().unwrap().len(), 2);
            // Stage b cannot close before stage a's last *dep* completed.
            assert!(rep.stages[1].close_secs >= rep.stages[0].open_secs);
            assert!(rep.sim_seconds >= rep.stages[1].close_secs);
            // Barrier charges two startups and forbids overlap entirely.
            match mode {
                ExecMode::Barrier => {
                    assert_eq!(rep.max_stage_overlap, 1);
                    // Stage b re-pays the 1 s job startup after stage a
                    // closes (f64 conversion leaves sub-ns slack).
                    assert!(rep.stages[1].open_secs >= rep.stages[0].close_secs + 0.999);
                    assert_eq!(rep.stages[1].eager_units, 0);
                }
                ExecMode::Pipelined => {
                    assert!(rep.sim_seconds >= 1.0, "single startup still charged");
                }
            }
        }
    }

    #[test]
    fn pipelined_and_barrier_values_are_bit_identical() {
        let run = |mode| {
            let shared = std::sync::Arc::new(Mutex::new(BTreeMap::new()));
            let a = mk_stage(&shared, "a", 0, vec![], vec![vec![]; 4]);
            let mut b = mk_stage(
                &shared,
                "b",
                1,
                vec![Gate::Planned(0)],
                (0..4).map(|u| vec![UnitRef { stage: 0, unit: u }]).collect(),
            );
            b.fail_first_attempt = true; // injected retries on every unit
            let c = mk_stage(
                &shared,
                "c",
                2,
                vec![Gate::Completed(1)],
                vec![vec![UnitRef { stage: 1, unit: 0 }, UnitRef { stage: 1, unit: 3 }]],
            );
            let registry = Registry::new();
            run_dag(&test_cfg(), &[&a, &b, &c], mode, &registry).expect("dag");
            let mut all = a.values.lock().unwrap().clone();
            all.extend(b.values.lock().unwrap().iter());
            all.extend(c.values.lock().unwrap().iter());
            all
        };
        assert_eq!(run(ExecMode::Pipelined), run(ExecMode::Barrier));
    }

    /// Tree-merge shape over one upstream stage: units 0..4 are leaves
    /// (one per upstream unit), 4 and 5 combine pairs, 6 is the root.
    fn tree_deps() -> Vec<Vec<UnitRef>> {
        let up = |u| UnitRef { stage: 0, unit: u };
        let own = |u| UnitRef { stage: 1, unit: u };
        vec![
            vec![up(0)],
            vec![up(1)],
            vec![up(2)],
            vec![up(3)],
            vec![own(0), own(1)],
            vec![own(2), own(3)],
            vec![own(4), own(5)],
        ]
    }

    #[test]
    fn intra_stage_tree_deps_run_in_both_modes_with_identical_values() {
        let run = |mode, fail_first| {
            let shared = std::sync::Arc::new(Mutex::new(BTreeMap::new()));
            let a = mk_stage(&shared, "a", 0, vec![], vec![vec![]; 4]);
            let mut t = mk_stage(&shared, "tree", 1, vec![Gate::Planned(0)], tree_deps());
            t.fail_first_attempt = fail_first;
            let registry = Registry::new();
            let rep = run_dag(&test_cfg(), &[&a, &t], mode, &registry).expect("dag");
            assert_eq!(rep.stages[1].units, 7);
            assert_eq!(t.finalized.load(Ordering::Relaxed), 1);
            assert_eq!(t.values.lock().unwrap().len(), 7);
            t.values.lock().unwrap().clone()
        };
        let baseline = run(ExecMode::Pipelined, false);
        assert_eq!(baseline, run(ExecMode::Barrier, false));
        // Injected retries on every tree unit must not change a bit
        // (children are re-read from the merged sink, never consumed).
        assert_eq!(baseline, run(ExecMode::Pipelined, true));
        assert_eq!(baseline, run(ExecMode::Barrier, true));
    }

    #[test]
    fn intra_stage_forward_dep_is_rejected_at_plan_time() {
        let shared = std::sync::Arc::new(Mutex::new(BTreeMap::new()));
        // Unit 0 depends on unit 1 of its own stage: forward reference.
        let bad = mk_stage(
            &shared,
            "bad",
            0,
            vec![],
            vec![vec![UnitRef { stage: 0, unit: 1 }], vec![]],
        );
        let registry = Registry::new();
        let err = run_dag(&test_cfg(), &[&bad], ExecMode::Pipelined, &registry).unwrap_err();
        assert!(err.to_string().contains("earlier unit"), "{err}");
    }

    #[test]
    fn zero_unit_stages_complete_and_gate_downstream() {
        let shared = std::sync::Arc::new(Mutex::new(BTreeMap::new()));
        let empty = mk_stage(&shared, "empty", 0, vec![], vec![]);
        let after = mk_stage(&shared, "after", 1, vec![Gate::Completed(0)], vec![vec![]]);
        let registry = Registry::new();
        let rep =
            run_dag(&test_cfg(), &[&empty, &after], ExecMode::Pipelined, &registry).unwrap();
        assert_eq!(rep.stages[0].units, 0);
        assert_eq!(after.values.lock().unwrap().len(), 1);
        assert_eq!(empty.finalized.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn gate_cycles_are_reported_not_hung() {
        let shared = std::sync::Arc::new(Mutex::new(BTreeMap::new()));
        let a = mk_stage(&shared, "a", 0, vec![Gate::Completed(1)], vec![vec![]]);
        let b = mk_stage(&shared, "b", 1, vec![Gate::Completed(0)], vec![vec![]]);
        let registry = Registry::new();
        let err = run_dag(&test_cfg(), &[&a, &b], ExecMode::Pipelined, &registry).unwrap_err();
        assert!(err.to_string().contains("stalled"), "{err}");
    }

    #[test]
    fn permanent_unit_failure_aborts_with_the_unit_error() {
        let shared = std::sync::Arc::new(Mutex::new(BTreeMap::new()));
        struct AlwaysFail;
        impl DagStage for AlwaysFail {
            fn name(&self) -> &'static str {
                "doomed"
            }
            fn plan(&self) -> Result<StagePlan> {
                Ok(StagePlan {
                    units: vec![UnitSpec::default()],
                    plan_io_secs: 0.0,
                })
            }
            fn run_unit(
                &self,
                _unit: usize,
                _handle: &TaskHandle,
                _node: NodeId,
            ) -> Result<Option<UnitOutput>> {
                Err(DifetError::Job("injected permafail".into()))
            }
            fn merge(&self, _unit: usize, _payload: Box<dyn Any + Send>) -> Result<()> {
                Ok(())
            }
        }
        let ok = mk_stage(&shared, "fine", 0, vec![], vec![vec![]]);
        let doomed = AlwaysFail;
        let registry = Registry::new();
        let err = run_dag(
            &test_cfg(),
            &[&ok as &dyn DagStage, &doomed],
            ExecMode::Pipelined,
            &registry,
        )
        .unwrap_err();
        assert!(err.to_string().contains("injected permafail"), "{err}");
    }

    #[test]
    fn queue_depth_and_overlap_gauges_are_registered() {
        let shared = std::sync::Arc::new(Mutex::new(BTreeMap::new()));
        let a = mk_stage(&shared, "a", 0, vec![], vec![vec![]; 3]);
        let registry = Registry::new();
        let rep = run_dag(&test_cfg(), &[&a], ExecMode::Pipelined, &registry).unwrap();
        assert!(registry.gauge("dag_queue_depth_max_a").get() >= 1.0);
        assert_eq!(
            registry.gauge("dag_stage_overlap_max").get(),
            rep.max_stage_overlap as f64
        );
        assert_eq!(rep.max_stage_overlap, 1);
    }
}
