//! Tree-shaped distributed reduction over a DAG stage's unit outputs.
//!
//! The PR-5 runtime distributed every *map*-shaped computation, but each
//! stage's reduction still ran as a serial loop on the coordinator —
//! the census fold in `ExtractStage::finalize`, the pair-result collect
//! in `AlignStage::plan`, and the union-find label merge in
//! `LabelStage::finalize`.  Those loops are O(units) on one thread and
//! are exactly the Amdahl term that collapsed parallel efficiency at
//! 4+ nodes (BENCH_5: 0.29 at 4 nodes).
//!
//! [`TreeMergeStage`] replaces a serial fold with a log-depth merge
//! tree scheduled as ordinary DAG units:
//!
//! * **leaves** materialize contiguous runs of upstream unit outputs
//!   (`[lo, hi)` in upstream unit order), released per-run as soon as
//!   *those* upstream units merge — reduction overlaps the map stage;
//! * **internal units** combine their children's parts, declared as
//!   intra-stage backward deps (`child < parent` in unit order), so the
//!   runtime releases each combine the moment its children merged.
//!
//! Determinism: the tree shape is fixed at plan time (a pure function
//! of the upstream unit count, the cluster geometry, and the optional
//! shape seed — never of the schedule), every combine receives its
//! children in upstream order, and the part algebra of each
//! [`TreeReducer`] is associative over contiguous runs.  Any tree shape
//! therefore folds to bits identical to the serial left fold, which is
//! what lets retries, speculation, and barrier-vs-pipelined schedules
//! all land on the same answer — property-tested over random shapes in
//! this module's tests and end-to-end in `rust/tests/vectorize_e2e.rs`.
//!
//! Fault tolerance: parts are stored as `Arc`s and children are only
//! ever *cloned*, never consumed — a retried or speculative combine can
//! re-read its children at any point.  Only `finalize` consumes the
//! root.

use std::any::Any;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::cluster::CostModel;
use crate::config::Config;
use crate::dfs::{Dfs, NodeId};
use crate::trace::UnitKind;
use crate::util::{DifetError, Result};
use crate::vector::{band_part, band_part_output, merge_band_parts, BandPart};

use super::dag::{DagStage, Gate, StagePlan, UnitOutput, UnitRef, UnitSpec};
use super::driver::JobHooks;
use super::job::{ImageCensus, PairResult};
use super::shuffle;
use super::scheduler::TaskHandle;
use super::stages::{injected_failure, ExtractStage, LabelStage, PairStage};

/// The merge algebra a [`TreeMergeStage`] folds.  Implementations must
/// be associative over *contiguous runs of upstream units*: combining
/// `[lo, mid)` with `[mid, hi)` must equal materializing `[lo, hi)`
/// directly — that (plus the fixed plan-time shape) is the whole
/// bit-identity argument.
pub trait TreeReducer: Sync {
    /// One subtree's value: the fold of a contiguous run of upstream
    /// unit outputs.
    type Part: Send + Sync + 'static;

    /// Per-upstream-unit locality hints; the length defines the
    /// upstream unit count (so this also pins the leaf ranges).
    fn fan_in(&self) -> Result<Vec<Vec<NodeId>>>;

    /// Materialize upstream units `[lo, hi)` into a part, returning the
    /// part plus modeled I/O seconds spent fetching the inputs.
    fn leaf(&self, lo: usize, hi: usize, node: NodeId) -> Result<(Self::Part, f64)>;

    /// Fold `children` — contiguous sibling parts in upstream order —
    /// into their parent part.
    fn combine(&self, children: Vec<Arc<Self::Part>>) -> Result<Self::Part>;

    /// Install the root part (the full fold) into its destination sink.
    fn finish(&self, root: Arc<Self::Part>) -> Result<()>;
}

/// One node of the planned merge tree.
struct TreeNode {
    /// Child unit indices (empty for leaves).  Always `< ` this node's
    /// own index: the tree is built bottom-up, so intra-stage deps are
    /// backward references, which is what the DAG validator requires.
    children: Vec<usize>,
    /// Upstream unit range `[lo, hi)` this subtree covers.
    lo: usize,
    hi: usize,
    preferred: Vec<NodeId>,
}

/// A log-depth reduction stage over the outputs of `upstream_index`.
///
/// Leaves span `ceil(n_upstream / leaf_target)` upstream units each,
/// where `leaf_target ≈ 2× the cluster's slot count` — enough leaves to
/// keep every slot busy without drowning small merges in per-task
/// overhead.  Internal levels pair adjacent siblings (or, with
/// [`TreeMergeStage::with_shape_seed`], group 2–3 of them pseudo-
/// randomly — the property tests' lever for exercising arbitrary
/// shapes); an odd node out is carried up a level rather than wrapped
/// in a pointless single-child unit.
pub struct TreeMergeStage<'a, R: TreeReducer> {
    name: &'static str,
    /// This stage's own index in the DAG's stage array (intra-stage
    /// deps are self-referential, so the stage must know its address).
    stage_index: usize,
    upstream_index: usize,
    leaf_target: usize,
    shape_seed: Option<u64>,
    reducer: R,
    hooks: &'a JobHooks,
    planned: Mutex<Option<Arc<Vec<TreeNode>>>>,
    parts: Mutex<Vec<Option<Arc<R::Part>>>>,
}

impl<'a, R: TreeReducer> TreeMergeStage<'a, R> {
    pub fn new(
        name: &'static str,
        cfg: &Config,
        stage_index: usize,
        upstream_index: usize,
        reducer: R,
        hooks: &'a JobHooks,
    ) -> Self {
        TreeMergeStage {
            name,
            stage_index,
            upstream_index,
            leaf_target: (cfg.cluster.nodes * cfg.cluster.slots_per_node * 2).max(4),
            shape_seed: None,
            reducer,
            hooks,
            planned: Mutex::new(None),
            parts: Mutex::new(Vec::new()),
        }
    }

    /// Randomize the tree shape (group sizes 2–3 drawn from a seeded
    /// xorshift).  Same seed ⇒ same shape; the fold result is shape-
    /// independent by the [`TreeReducer`] contract.
    pub fn with_shape_seed(mut self, seed: u64) -> Self {
        self.shape_seed = Some(seed);
        self
    }

    /// The reducer, for reading back sinks it owns itself.
    pub fn reducer(&self) -> &R {
        &self.reducer
    }

    fn plan_info(&self) -> Arc<Vec<TreeNode>> {
        self.planned
            .lock()
            .unwrap()
            .clone()
            .expect("tree-merge stage used before plan")
    }
}

/// Union of locality hints, first-seen order, deduplicated.
fn union_preferred(sets: &[&[NodeId]]) -> Vec<NodeId> {
    let mut out: Vec<NodeId> = Vec::new();
    for set in sets {
        for &n in *set {
            if !out.contains(&n) {
                out.push(n);
            }
        }
    }
    out
}

impl<R: TreeReducer> DagStage for TreeMergeStage<'_, R> {
    fn name(&self) -> &'static str {
        self.name
    }

    fn unit_kind(&self, unit: usize) -> UnitKind {
        // Valid once planned (the runtime only asks after `plan`).  The
        // root is always the last node built (asserted in `plan`).
        let nodes = self.plan_info();
        if nodes[unit].children.is_empty() {
            UnitKind::MergeLeaf
        } else if unit == nodes.len() - 1 {
            UnitKind::MergeRoot
        } else {
            UnitKind::MergeInternal
        }
    }

    fn gates(&self) -> Vec<Gate> {
        // The shape depends on the upstream unit count, so plan once the
        // upstream stage has planned (NOT completed — leaves release
        // per-run as their upstream units merge).
        vec![Gate::Planned(self.upstream_index)]
    }

    fn plan(&self) -> Result<StagePlan> {
        let fan_in = self.reducer.fan_in()?;
        let n_up = fan_in.len();
        if n_up == 0 {
            return Err(DifetError::Job(format!(
                "{}: upstream stage planned zero units; nothing to merge",
                self.name
            )));
        }
        let span = n_up.div_ceil(self.leaf_target);

        let mut nodes: Vec<TreeNode> = Vec::new();
        let mut level: Vec<usize> = Vec::new();
        let mut lo = 0;
        while lo < n_up {
            let hi = (lo + span).min(n_up);
            let sets: Vec<&[NodeId]> = fan_in[lo..hi].iter().map(|v| v.as_slice()).collect();
            nodes.push(TreeNode {
                children: Vec::new(),
                lo,
                hi,
                preferred: union_preferred(&sets),
            });
            level.push(nodes.len() - 1);
            lo = hi;
        }

        // Internal levels, bottom-up.  The xorshift stream is consumed
        // in one deterministic plan-time pass — shape never depends on
        // the schedule.
        let mut rng = self.shape_seed;
        while level.len() > 1 {
            let mut next = Vec::new();
            let mut i = 0;
            while i < level.len() {
                let remaining = level.len() - i;
                if remaining == 1 {
                    // Odd node out: carry it up instead of wrapping it
                    // in a single-child unit.
                    next.push(level[i]);
                    break;
                }
                let group = match &mut rng {
                    None => 2,
                    Some(s) => {
                        *s ^= *s << 13;
                        *s ^= *s >> 7;
                        *s ^= *s << 17;
                        2 + (*s % 2) as usize
                    }
                }
                .min(remaining);
                let children: Vec<usize> = level[i..i + group].to_vec();
                let sets: Vec<&[NodeId]> = children
                    .iter()
                    .map(|&c| nodes[c].preferred.as_slice())
                    .collect();
                nodes.push(TreeNode {
                    lo: nodes[children[0]].lo,
                    hi: nodes[children[group - 1]].hi,
                    preferred: union_preferred(&sets),
                    children,
                });
                next.push(nodes.len() - 1);
                i += group;
            }
            level = next;
        }
        debug_assert_eq!(nodes.last().map(|n| (n.lo, n.hi)), Some((0, n_up)));

        let units = nodes
            .iter()
            .map(|n| UnitSpec {
                deps: if n.children.is_empty() {
                    (n.lo..n.hi)
                        .map(|u| UnitRef { stage: self.upstream_index, unit: u })
                        .collect()
                } else {
                    n.children
                        .iter()
                        .map(|&c| UnitRef { stage: self.stage_index, unit: c })
                        .collect()
                },
                preferred_nodes: n.preferred.clone(),
            })
            .collect();
        *self.parts.lock().unwrap() = vec![None; nodes.len()];
        *self.planned.lock().unwrap() = Some(Arc::new(nodes));
        Ok(StagePlan { units, plan_io_secs: 0.0 })
    }

    fn run_unit(
        &self,
        unit: usize,
        handle: &TaskHandle,
        node: NodeId,
    ) -> Result<Option<UnitOutput>> {
        injected_failure(self.hooks, self.name, unit, handle)?;
        let nodes = self.plan_info();
        let tree_node = &nodes[unit];
        if handle.cancelled() {
            return Ok(None);
        }
        let t0 = std::time::Instant::now();
        let (part, io_secs) = if tree_node.children.is_empty() {
            self.reducer.leaf(tree_node.lo, tree_node.hi, node)?
        } else {
            // Children merged before this unit was released (declared
            // deps); clone their Arcs under a brief lock and combine
            // outside it.
            let children: Vec<Arc<R::Part>> = {
                let parts = self.parts.lock().unwrap();
                tree_node
                    .children
                    .iter()
                    .map(|&c| {
                        parts[c].clone().ok_or_else(|| {
                            DifetError::Job(format!(
                                "{}: child part {c} missing for unit {unit}",
                                self.name
                            ))
                        })
                    })
                    .collect::<Result<_>>()?
            };
            (self.reducer.combine(children)?, 0.0)
        };
        let compute_ns = t0.elapsed().as_nanos() as u64;
        if handle.cancelled() {
            return Ok(None);
        }
        Ok(Some(UnitOutput {
            payload: Box::new(part),
            compute_ns,
            io_secs,
        }))
    }

    fn merge(&self, unit: usize, payload: Box<dyn Any + Send>) -> Result<()> {
        // Downcast before taking the lock (keep the critical section to
        // the slot store).
        let part = payload
            .downcast::<R::Part>()
            .map_err(|_| DifetError::Job(format!("{}: wrong payload type", self.name)))?;
        self.parts.lock().unwrap()[unit] = Some(Arc::new(*part));
        Ok(())
    }

    fn finalize(&self) -> Result<()> {
        let root = {
            let parts = self.parts.lock().unwrap();
            for (unit, part) in parts.iter().enumerate() {
                if part.is_none() {
                    return Err(DifetError::Job(format!(
                        "{}: unit {unit} lost its part",
                        self.name
                    )));
                }
            }
            // Clone (never take) — a late losing twin of an internal
            // unit may still read its children.  The root is the last
            // node built.
            parts.last().and_then(|p| p.clone()).unwrap()
        };
        self.reducer.finish(root)
    }
}

// ---------------------------------------------------------------------------
// The three reducers: census, registration, labels.
// ---------------------------------------------------------------------------

/// Census fold for an [`ExtractStage`] in defer mode: parts are maps
/// keyed `(image_id, algorithm_index)`.  Upstream units own disjoint
/// image sets, so every combine is a disjoint map union — trivially
/// associative and order-free.
pub struct CensusTreeReducer<'a> {
    extract: &'a ExtractStage<'a>,
}

impl<'a> CensusTreeReducer<'a> {
    pub fn new(extract: &'a ExtractStage<'a>) -> Self {
        CensusTreeReducer { extract }
    }
}

impl TreeReducer for CensusTreeReducer<'_> {
    type Part = BTreeMap<(u64, usize), ImageCensus>;

    fn fan_in(&self) -> Result<Vec<Vec<NodeId>>> {
        Ok((0..self.extract.unit_count())
            .map(|u| self.extract.unit_preferred(u))
            .collect())
    }

    fn leaf(&self, lo: usize, hi: usize, _node: NodeId) -> Result<(Self::Part, f64)> {
        // The censuses are in-memory slots on the extract stage (no DFS
        // hop), so leaf I/O is free.
        let mut part = BTreeMap::new();
        for u in lo..hi {
            for per_image in self.extract.unit_censuses(u)?.iter() {
                for (alg, census) in per_image.iter().enumerate() {
                    part.insert((census.image_id, alg), census.clone());
                }
            }
        }
        Ok((part, 0.0))
    }

    fn combine(&self, children: Vec<Arc<Self::Part>>) -> Result<Self::Part> {
        let mut out = Self::Part::new();
        for child in children {
            for (key, census) in child.iter() {
                if out.insert(*key, census.clone()).is_some() {
                    return Err(DifetError::Job(format!(
                        "census merge: image {} algorithm {} seen twice",
                        key.0, key.1
                    )));
                }
            }
        }
        Ok(out)
    }

    fn finish(&self, root: Arc<Self::Part>) -> Result<()> {
        self.extract.install_censuses(root.as_ref().clone())
    }
}

/// Pair-result collect for a [`PairStage`]: parts are slices of the
/// results in unit order, so contiguous combines are concatenations —
/// the root is byte-for-byte the vector the serial collect built.  The
/// merged vector stays here (read via [`PairTreeReducer::results`]);
/// a downstream [`super::stages::AlignStage`] consumes it.
pub struct PairTreeReducer<'a> {
    pairs: &'a PairStage<'a>,
    merged: Mutex<Option<Vec<PairResult>>>,
}

impl<'a> PairTreeReducer<'a> {
    pub fn new(pairs: &'a PairStage<'a>) -> Self {
        PairTreeReducer { pairs, merged: Mutex::new(None) }
    }

    /// The collected pair results, unit order (valid after the merge
    /// stage completed).
    pub fn results(&self) -> Result<Vec<PairResult>> {
        self.merged
            .lock()
            .unwrap()
            .clone()
            .ok_or_else(|| DifetError::Job("pair merge read before completion".into()))
    }
}

impl TreeReducer for PairTreeReducer<'_> {
    type Part = Vec<PairResult>;

    fn fan_in(&self) -> Result<Vec<Vec<NodeId>>> {
        Ok((0..self.pairs.unit_count())
            .map(|u| self.pairs.unit_preferred(u))
            .collect())
    }

    fn leaf(&self, lo: usize, hi: usize, _node: NodeId) -> Result<(Self::Part, f64)> {
        let mut part = Vec::with_capacity(hi - lo);
        for u in lo..hi {
            part.push(self.pairs.result_of(u)?);
        }
        Ok((part, 0.0))
    }

    fn combine(&self, children: Vec<Arc<Self::Part>>) -> Result<Self::Part> {
        let mut out = Vec::with_capacity(children.iter().map(|c| c.len()).sum());
        for child in children {
            out.extend(child.iter().cloned());
        }
        Ok(out)
    }

    fn finish(&self, root: Arc<Self::Part>) -> Result<()> {
        *self.merged.lock().unwrap() = Some(root.as_ref().clone());
        Ok(())
    }
}

/// Label-band fold for a [`LabelStage`] in defer mode: parts are
/// [`BandPart`]s — canonically relabeled row bands with fragment and
/// seam-union tallies.  `rust/src/vector/label.rs` proves (and
/// property-tests) that merging adjacent bands is associative and lands
/// bit-identically on the serial `merge_tile_labels` fold, so any tree
/// over contiguous bands is safe.
///
/// Unlike the in-memory reducers above, leaves fetch the upstream
/// units' shuffled label files from DFS — that is real modeled I/O, and
/// it is exactly the fetch the serial finalize loop used to do one file
/// at a time on the coordinator.
pub struct LabelTreeReducer<'a> {
    label: &'a LabelStage<'a>,
    dfs: &'a Dfs,
    cost: CostModel,
}

impl<'a> LabelTreeReducer<'a> {
    pub fn new(cfg: &Config, dfs: &'a Dfs, label: &'a LabelStage<'a>) -> Self {
        LabelTreeReducer { label, dfs, cost: CostModel::new(&cfg.cluster) }
    }
}

impl TreeReducer for LabelTreeReducer<'_> {
    type Part = BandPart;

    fn fan_in(&self) -> Result<Vec<Vec<NodeId>>> {
        Ok((0..self.label.unit_count())
            .map(|u| self.label.unit_preferred(u))
            .collect())
    }

    fn leaf(&self, lo: usize, hi: usize, node: NodeId) -> Result<(Self::Part, f64)> {
        let mut io_secs = 0.0;
        let mut acc: Option<BandPart> = None;
        for u in lo..hi {
            let (path, want_id) = self.label.unit_labels_file(u);
            let (bytes, stats) = self.dfs.read_file(&path, node)?;
            io_secs += self.cost.split_input(stats.local_bytes, stats.remote_bytes);
            let (id, tile) = shuffle::decode_labels(&bytes)?;
            if id != want_id {
                return Err(DifetError::Job(format!(
                    "label file routing mixup: wanted {want_id}, got {id}"
                )));
            }
            let next = band_part(tile)?;
            acc = Some(match acc {
                None => next,
                Some(prev) => merge_band_parts(&prev, &next)?,
            });
        }
        acc.map(|part| (part, io_secs))
            .ok_or_else(|| DifetError::Job("label merge leaf spans zero bands".into()))
    }

    fn combine(&self, children: Vec<Arc<Self::Part>>) -> Result<Self::Part> {
        let mut iter = children.into_iter();
        let first = iter
            .next()
            .ok_or_else(|| DifetError::Job("label merge combine got no children".into()))?;
        let mut acc = first.as_ref().clone();
        for child in iter {
            acc = merge_band_parts(&acc, &child)?;
        }
        Ok(acc)
    }

    fn finish(&self, root: Arc<Self::Part>) -> Result<()> {
        let (width, height) = self.label.dims();
        let merged = band_part_output(width, height, root.as_ref().clone())?;
        self.label.install_merged(merged)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A reducer over plain integer ranges: leaf(lo,hi) = the vector
    /// [lo, hi), combine = concat.  The root must be [0, n) exactly —
    /// any dropped, duplicated or reordered upstream unit is visible.
    struct RangeReducer {
        n: usize,
        sink: Mutex<Option<Vec<usize>>>,
    }

    impl TreeReducer for RangeReducer {
        type Part = Vec<usize>;
        fn fan_in(&self) -> Result<Vec<Vec<NodeId>>> {
            Ok(vec![Vec::new(); self.n])
        }
        fn leaf(&self, lo: usize, hi: usize, _node: NodeId) -> Result<(Self::Part, f64)> {
            Ok(((lo..hi).collect(), 0.0))
        }
        fn combine(&self, children: Vec<Arc<Self::Part>>) -> Result<Self::Part> {
            Ok(children.iter().flat_map(|c| c.iter().copied()).collect())
        }
        fn finish(&self, root: Arc<Self::Part>) -> Result<()> {
            *self.sink.lock().unwrap() = Some(root.as_ref().clone());
            Ok(())
        }
    }

    fn leaf_count(plan: &StagePlan, upstream: usize) -> usize {
        plan.units
            .iter()
            .filter(|u| u.deps.iter().all(|d| d.stage == upstream))
            .count()
    }

    #[test]
    fn plan_builds_contiguous_backward_trees() {
        let hooks = JobHooks::default();
        let mut cfg = Config::default();
        cfg.cluster.nodes = 2;
        cfg.cluster.slots_per_node = 2;
        for n in [1usize, 2, 7, 16, 33, 120] {
            for seed in [None, Some(7u64), Some(0xDEADBEEF)] {
                let reducer = RangeReducer { n, sink: Mutex::new(None) };
                let mut stage = TreeMergeStage::new("t", &cfg, 1, 0, reducer, &hooks);
                if let Some(s) = seed {
                    stage = stage.with_shape_seed(s);
                }
                let plan = stage.plan().unwrap();
                // leaf_target = (2*2*2).max(4) = 8 leaves max.
                let leaves = leaf_count(&plan, 0);
                assert!(leaves <= 8, "n={n}: {leaves} leaves");
                assert!(leaves >= 1);
                // Every dep is either upstream or a backward self-ref.
                for (u, spec) in plan.units.iter().enumerate() {
                    for d in &spec.deps {
                        if d.stage == 1 {
                            assert!(d.unit < u, "forward self-dep {} -> {u}", d.unit);
                        } else {
                            assert_eq!(d.stage, 0);
                            assert!(d.unit < n);
                        }
                    }
                    assert!(!spec.deps.is_empty());
                }
                // Exactly one root: a unit nothing else depends on.
                let mut depended: Vec<bool> = vec![false; plan.units.len()];
                for spec in &plan.units {
                    for d in &spec.deps {
                        if d.stage == 1 {
                            depended[d.unit] = true;
                        }
                    }
                }
                let roots = depended.iter().filter(|&&d| !d).count();
                assert_eq!(roots, 1, "n={n} seed={seed:?}");
                assert!(!depended[plan.units.len() - 1], "root must be last");
            }
        }
    }

    #[test]
    fn same_seed_same_shape_and_any_shape_folds_identically() {
        let hooks = JobHooks::default();
        let cfg = Config::default();
        for seed in [None, Some(1u64), Some(2), Some(999)] {
            let reducer = RangeReducer { n: 37, sink: Mutex::new(None) };
            let mut stage = TreeMergeStage::new("t", &cfg, 1, 0, reducer, &hooks);
            if let Some(s) = seed {
                stage = stage.with_shape_seed(s);
            }
            let plan = stage.plan().unwrap();
            // Drive the stage by hand in unit order (deps are backward,
            // so ascending order satisfies them).
            let handle = TaskHandle::test_handle();
            for u in 0..plan.units.len() {
                let out = stage.run_unit(u, &handle, NodeId(0)).unwrap().unwrap();
                stage.merge(u, out.payload).unwrap();
            }
            stage.finalize().unwrap();
            let folded = stage.reducer().sink.lock().unwrap().clone().unwrap();
            assert_eq!(folded, (0..37).collect::<Vec<_>>(), "seed={seed:?}");
        }
    }
}
