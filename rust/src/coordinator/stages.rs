//! The job shapes as [`DagStage`] definitions.
//!
//! Everything that used to be a bespoke driver loop is now per-stage
//! glue over the generic [`crate::coordinator::dag`] runtime:
//!
//! * [`IngestStage`] — bundle decode as a first-class stage (one unit
//!   per record).  Decoded scenes flow through the existing
//!   [`super::backpressure::BoundedQueue`] into per-unit slots, so
//!   decode overlaps extraction instead of running serially before the
//!   DAG and being mis-billed to the extract span.
//! * [`ExtractStage`] — map-shaped fused extraction (one unit per HIB
//!   split).  With [`ExtractStage::publish_features`] enabled, each map
//!   unit also writes its images' keypoints+descriptors into CRC-guarded
//!   DFS feature files the moment the unit completes — the unit-level
//!   hand-off a downstream [`PairStage`] pipelines against.  With
//!   [`ExtractStage::defer_merge`], the census fold moves off the
//!   coordinator onto a downstream tree-merge stage
//!   ([`super::merge::TreeMergeStage`]).
//! * [`PairStage`] — reduce-shaped scene-pair registration.  Each pair
//!   unit declares the extract units owning its two scenes as inputs, so
//!   a pair matches as soon as *its* feature files exist, not when the
//!   whole extraction stage barriers.
//! * [`AlignStage`] — the least-squares solve, sharded one unit per
//!   connected component of the measurement graph (components are
//!   independent systems; [`crate::mosaic::AlignProblem`] makes the
//!   shards bit-equal to the serial solve by construction).
//! * [`CompositeStage`] — canvas-tile compositing; plans once the
//!   alignment exists, then all tiles run in parallel.  Scenes come
//!   either from the caller or from an upstream [`IngestStage`].
//! * [`LabelStage`] — band-tile mask labeling.  Over a mosaic, each
//!   band unit declares the canvas tiles covering its rows as inputs, so
//!   labeling starts while other canvas tiles are still compositing.
//!   The union-find merge runs at finalize, or — with
//!   [`LabelStage::defer_merge`] — as a distributed tree of pairwise
//!   band merges.
//!
//! Determinism: every unit body here is byte-for-byte the computation
//! the old drivers ran, a pure function of the stage spec and its
//! declared inputs — which is what makes pipelined and barrier schedules
//! (and any retry/speculation history) bit-identical, as the e2e suites
//! assert against the sequential baselines.

use std::any::Any;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::cluster::CostModel;
use crate::config::Config;
use crate::dfs::{Dfs, NodeId};
use crate::features::matching::{match_descriptors_while, ransac_translation};
use crate::features::nms::rank_truncate;
use crate::features::{self, Descriptors};
use crate::hib::{self, BundleReader, RecordMeta};
use crate::imagery::tiler::{extract_tile_f32, TileIter};
use crate::imagery::Rgba8Image;
use crate::metrics::{Counter, Gauge, Histogram, Registry};
use crate::trace::UnitKind;
use crate::mosaic::{Canvas, GlobalAlignment, OverlapStat};
use crate::util::{DifetError, Result};
use crate::vector::{Labels, Mask, MergeStats, ObjectStats};

use super::backpressure::BoundedQueue;
use super::dag::{DagStage, Gate, StagePlan, StageReport, UnitOutput, UnitRef, UnitSpec};
use super::driver::{JobHooks, TileExecutor};
use super::job::{
    mapper_retention, pair_seed, CanvasTile, FusedJobSpec, ImageCensus, IngestTask, JobReport,
    LabelTile, MapOutput, MosaicReport, MosaicSpec, PairResult, PairTask, RegistrationReport,
    RegistrationSpec, VectorReport, VectorSpec,
};
use super::scheduler::{TaskDescriptor, TaskHandle};
use super::shuffle;

/// DFS path of one scene's shuffled feature file.
pub(crate) fn feature_path(dir: &str, algorithm: &str, id: u64) -> String {
    format!("{dir}/{algorithm}/{id}")
}

/// Nodes holding replicas of any of `paths`, deduplicated, best first.
pub(crate) fn preferred_for_paths(dfs: &Dfs, paths: &[String]) -> Vec<NodeId> {
    let mut preferred = Vec::new();
    for path in paths {
        if let Ok(meta) = dfs.namenode().file_meta(path) {
            if let Ok(nodes) = dfs.locate_range(path, 0, meta.len) {
                for n in nodes {
                    if !preferred.contains(&n) {
                        preferred.push(n);
                    }
                }
            }
        }
    }
    preferred
}

/// Failure injection shared by every stage body (the paper's "crashed
/// JVM": an attempt dies before doing any work).
pub(crate) fn injected_failure(
    hooks: &JobHooks,
    what: &str,
    unit: usize,
    handle: &TaskHandle,
) -> Result<()> {
    if let Some(f) = &hooks.fail {
        if f(unit, handle.attempt) {
            return Err(DifetError::Job(format!(
                "injected failure ({what} {unit}, attempt {})",
                handle.attempt
            )));
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Ingest: bundle decode as a first-class stage.
// ---------------------------------------------------------------------------

/// Bundle decode as a DAG stage: one unit per record, each range-reading
/// and decoding its record wherever the scheduler placed it.  Decoded
/// scenes ride the existing [`BoundedQueue`] (capacity-bounded, so a
/// burst of decoders backpressures instead of piling images up) into
/// per-unit slots; slot writes are first-wins idempotent, so retries and
/// speculative twins — which decode identical bytes — are harmless.
///
/// This replaces the pre-DAG serial decode loop the stitch driver ran,
/// which both delayed every map unit behind the full-bundle decode and
/// mis-billed decode time into the extract stage's bench span.
pub struct IngestStage<'a> {
    dfs: &'a Dfs,
    hooks: &'a JobHooks,
    cost: CostModel,
    bundle_path: String,
    records_counter: Arc<Counter>,
    decode_hist: Arc<Histogram>,
    planned: Mutex<Option<Arc<Vec<IngestTask>>>>,
    /// Decoded records in flight between a worker slot and the per-unit
    /// slots below.  Every pusher drains the queue right after its push,
    /// so a blocked pusher always has a draining predecessor — the queue
    /// cannot wedge.
    queue: BoundedQueue<(usize, u64, Rgba8Image)>,
    slots: Mutex<Vec<Option<(u64, Rgba8Image)>>>,
    scenes: Mutex<Option<Arc<Vec<(u64, Rgba8Image)>>>>,
}

impl<'a> IngestStage<'a> {
    pub fn new(
        cfg: &'a Config,
        dfs: &'a Dfs,
        bundle_path: &str,
        registry: &Registry,
        hooks: &'a JobHooks,
    ) -> Self {
        IngestStage {
            dfs,
            hooks,
            cost: CostModel::new(&cfg.cluster),
            bundle_path: bundle_path.to_string(),
            records_counter: registry.counter("records_ingested"),
            decode_hist: registry.histogram("ingest_decode_latency"),
            planned: Mutex::new(None),
            queue: BoundedQueue::new(4),
            slots: Mutex::new(Vec::new()),
            scenes: Mutex::new(None),
        }
    }

    fn plan_info(&self) -> Arc<Vec<IngestTask>> {
        self.planned
            .lock()
            .unwrap()
            .clone()
            .expect("ingest stage used before plan")
    }

    /// Move everything currently in the queue into the per-unit slots.
    /// The slots lock is held across the whole pop+insert loop, so after
    /// any drain returns, every item pushed before it is visible in the
    /// slots — `merge()` relies on this to observe its own unit's item.
    fn drain(&self) {
        let mut slots = self.slots.lock().unwrap();
        while let Some((unit, id, image)) = self.queue.try_pop() {
            if slots[unit].is_none() {
                slots[unit] = Some((id, image));
            }
        }
    }

    /// The decoded scene set, record order (valid after the stage
    /// completed).
    pub fn scenes(&self) -> Result<Arc<Vec<(u64, Rgba8Image)>>> {
        self.scenes
            .lock()
            .unwrap()
            .clone()
            .ok_or_else(|| DifetError::Job("ingest stage read before completion".into()))
    }
}

impl DagStage for IngestStage<'_> {
    fn name(&self) -> &'static str {
        "ingest"
    }

    fn unit_kind(&self, _unit: usize) -> UnitKind {
        UnitKind::Ingest
    }

    /// Plan: read the bundle index (jobtracker-side, like the extract
    /// plan), one unit per record with locality toward its byte range.
    fn plan(&self) -> Result<StagePlan> {
        let (bundle_bytes, _) = self.dfs.read_file(&self.bundle_path, NodeId(0))?;
        let reader = BundleReader::open(&bundle_bytes)?;
        let metas: Vec<RecordMeta> = reader.metas().to_vec();
        let total = bundle_bytes.len() as u64;
        let mut tasks = Vec::with_capacity(metas.len());
        for (i, meta) in metas.iter().enumerate() {
            let byte_start = meta.offset;
            let byte_end = metas.get(i + 1).map(|m| m.offset).unwrap_or(total);
            let preferred = self
                .dfs
                .locate_range(&self.bundle_path, byte_start, byte_end)
                .unwrap_or_default();
            tasks.push(IngestTask {
                record: i,
                image_id: meta.image_id,
                byte_start,
                byte_end,
                preferred_nodes: preferred,
            });
        }
        let units = tasks
            .iter()
            .map(|t| UnitSpec {
                deps: Vec::new(),
                preferred_nodes: t.preferred_nodes.clone(),
            })
            .collect();
        *self.slots.lock().unwrap() = vec![None; tasks.len()];
        *self.planned.lock().unwrap() = Some(Arc::new(tasks));
        Ok(StagePlan { units, plan_io_secs: 0.0 })
    }

    /// The unit body: range-read the record, decode it, hand it off
    /// through the bounded queue.
    fn run_unit(
        &self,
        unit: usize,
        handle: &TaskHandle,
        node: NodeId,
    ) -> Result<Option<UnitOutput>> {
        injected_failure(self.hooks, "ingest", unit, handle)?;
        let tasks = self.plan_info();
        let task = &tasks[unit];

        let (bytes, stats) =
            self.dfs
                .read_range(&self.bundle_path, task.byte_start, task.byte_end, node)?;
        let io_secs = self.cost.split_input(stats.local_bytes, stats.remote_bytes);
        if handle.cancelled() {
            return Ok(None);
        }
        let t0 = std::time::Instant::now();
        let (image_id, image, _) = hib::decode_record(&bytes)?;
        let compute_ns = t0.elapsed().as_nanos() as u64;
        if image_id != task.image_id {
            return Err(DifetError::Job(format!(
                "ingest record routing mixup: wanted {}, got {image_id}",
                task.image_id
            )));
        }
        self.decode_hist.observe(compute_ns as f64 * 1e-9);
        if handle.cancelled() {
            return Ok(None);
        }
        self.queue
            .push((unit, image_id, image))
            .map_err(|_| DifetError::Job("ingest queue closed mid-run".into()))?;
        self.drain();

        Ok(Some(UnitOutput {
            payload: Box::new(()),
            compute_ns,
            io_secs,
        }))
    }

    fn merge(&self, unit: usize, _payload: Box<dyn Any + Send>) -> Result<()> {
        // The winning attempt pushed before returning, and drain() holds
        // the slots lock across pop+insert — so after this drain, the
        // unit's scene is guaranteed present.
        self.drain();
        if self.slots.lock().unwrap()[unit].is_none() {
            return Err(DifetError::Job(format!(
                "ingest record {unit} missing after merge"
            )));
        }
        self.records_counter.inc();
        Ok(())
    }

    fn finalize(&self) -> Result<()> {
        self.drain();
        let mut slots = self.slots.lock().unwrap();
        let mut scenes = Vec::with_capacity(slots.len());
        for (unit, slot) in slots.iter_mut().enumerate() {
            // take(): the slots are never read again (a late losing twin
            // re-filling one is harmless), and this avoids doubling the
            // decoded corpus in memory.
            match slot.take() {
                Some(scene) => scenes.push(scene),
                None => {
                    return Err(DifetError::Job(format!(
                        "ingest record {unit} lost its scene"
                    )))
                }
            }
        }
        *self.scenes.lock().unwrap() = Some(Arc::new(scenes));
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Extract: the map-shaped fused-extraction stage.
// ---------------------------------------------------------------------------

struct ExtractPlanInfo {
    tasks: Vec<TaskDescriptor>,
    metas: Vec<RecordMeta>,
    /// image_id → owning unit (what downstream pair units depend on).
    image_unit: BTreeMap<u64, usize>,
}

/// Map-shaped fused extraction over one HIB bundle: one unit per
/// record-aligned split, every algorithm of the spec in one shared pass.
pub struct ExtractStage<'a> {
    cfg: &'a Config,
    dfs: &'a Dfs,
    executor: &'a dyn TileExecutor,
    spec: FusedJobSpec,
    hooks: &'a JobHooks,
    cost: CostModel,
    /// When set: each unit writes its images' censuses of algorithm
    /// `spec.algorithms[index]` into `dir` as CRC-guarded feature files.
    publish: Option<(String, usize)>,
    /// When set, `merge()` parks each unit's censuses in a per-unit slot
    /// instead of folding them into the coordinator map — a downstream
    /// tree-merge stage performs the fold and hands the result back via
    /// [`ExtractStage::install_censuses`].
    defer: bool,
    tiles_counter: Arc<Counter>,
    tile_hist: Arc<Histogram>,
    tiles: AtomicU64,
    planned: Mutex<Option<Arc<ExtractPlanInfo>>>,
    /// Per-unit deferred payloads (defer mode; indexed by unit).
    unit_censuses: Mutex<Vec<Option<Arc<Vec<Vec<ImageCensus>>>>>>,
    /// (image_id, algorithm index) → merged census.
    censuses: Mutex<BTreeMap<(u64, usize), ImageCensus>>,
}

impl<'a> ExtractStage<'a> {
    pub fn new(
        cfg: &'a Config,
        dfs: &'a Dfs,
        executor: &'a dyn TileExecutor,
        spec: FusedJobSpec,
        registry: &Registry,
        hooks: &'a JobHooks,
    ) -> Result<Self> {
        if spec.algorithms.len() != spec.per_image_caps.len() {
            return Err(DifetError::Config(
                "fused job: one per-image cap per algorithm required".into(),
            ));
        }
        Ok(ExtractStage {
            cfg,
            dfs,
            executor,
            spec,
            hooks,
            cost: CostModel::new(&cfg.cluster),
            publish: None,
            defer: false,
            tiles_counter: registry.counter("tiles_processed"),
            tile_hist: registry.histogram("tile_latency"),
            tiles: AtomicU64::new(0),
            planned: Mutex::new(None),
            unit_censuses: Mutex::new(Vec::new()),
            censuses: Mutex::new(BTreeMap::new()),
        })
    }

    /// Publish per-scene feature files of algorithm index `alg_index`
    /// into `feature_dir` from each map unit (pair-stage hand-off).
    pub fn publish_features(mut self, feature_dir: &str, alg_index: usize) -> Self {
        self.publish = Some((feature_dir.to_string(), alg_index));
        self
    }

    /// Defer the census fold to a downstream tree-merge stage: `merge()`
    /// becomes an O(1) slot store and `finalize()` only checks coverage.
    /// The merge stage installs the fold via
    /// [`ExtractStage::install_censuses`] before reports are read.
    pub fn defer_merge(mut self) -> Self {
        self.defer = true;
        self
    }

    fn plan_info(&self) -> Arc<ExtractPlanInfo> {
        self.planned
            .lock()
            .unwrap()
            .clone()
            .expect("extract stage used before plan")
    }

    /// Scene ids of the planned bundle, record order.
    pub fn scene_ids(&self) -> Vec<u64> {
        self.plan_info().metas.iter().map(|m| m.image_id).collect()
    }

    /// The unit owning an image (downstream unit-level deps).
    pub fn unit_of_image(&self, image_id: u64) -> Option<usize> {
        self.plan_info().image_unit.get(&image_id).copied()
    }

    /// A unit's data-local nodes (the split's replica holders).  The
    /// locality-aware scheduler runs the unit there when it can, and the
    /// unit publishes its feature files from wherever it ran — so these
    /// nodes are also the best locality guess for downstream pair units.
    pub fn unit_preferred(&self, unit: usize) -> Vec<NodeId> {
        self.plan_info().tasks[unit].preferred_nodes.clone()
    }

    /// Planned unit count (valid after plan).
    pub fn unit_count(&self) -> usize {
        self.plan_info().tasks.len()
    }

    /// One unit's deferred censuses (defer mode; valid once the unit
    /// merged — i.e. from a downstream unit that declared it as a dep).
    pub fn unit_censuses(&self, unit: usize) -> Result<Arc<Vec<Vec<ImageCensus>>>> {
        self.unit_censuses.lock().unwrap()[unit]
            .clone()
            .ok_or_else(|| DifetError::Job(format!("extract unit {unit} has not merged yet")))
    }

    /// Install the tree-merged census fold (defer mode).  Validates the
    /// same full-coverage invariant the serial finalize enforced.
    pub fn install_censuses(&self, merged: BTreeMap<(u64, usize), ImageCensus>) -> Result<()> {
        let expect = self.plan_info().metas.len() * self.spec.algorithms.len();
        if merged.len() != expect {
            return Err(DifetError::Job(format!(
                "census merge produced {} censuses, expected {expect}",
                merged.len()
            )));
        }
        *self.censuses.lock().unwrap() = merged;
        Ok(())
    }

    /// Merged per-image censuses of one algorithm, image id ascending.
    pub fn images(&self, alg_index: usize) -> Vec<ImageCensus> {
        self.censuses
            .lock()
            .unwrap()
            .iter()
            .filter(|((_, a), _)| *a == alg_index)
            .map(|(_, c)| c.clone())
            .collect()
    }

    /// Assemble the per-algorithm [`JobReport`]s (one per algorithm, in
    /// spec order) from this stage's slice of a finished DAG run.
    pub fn reports(
        &self,
        stage: &StageReport,
        sim_seconds: f64,
        wall_seconds: f64,
    ) -> Result<Vec<JobReport>> {
        let n_images = self.plan_info().metas.len();
        let mut counters = stage.scheduler_counters();
        counters.insert("tasks".into(), stage.units as u64);
        counters.insert("tiles".into(), self.tiles.load(Ordering::Relaxed));
        counters.insert("fused_algorithms".into(), self.spec.algorithms.len() as u64);
        let mut reports = Vec::with_capacity(self.spec.algorithms.len());
        for (i, alg) in self.spec.algorithms.iter().enumerate() {
            let images = self.images(i);
            if images.len() != n_images {
                return Err(DifetError::Job(format!(
                    "{alg}: merged {} images, bundle has {n_images}",
                    images.len()
                )));
            }
            reports.push(JobReport {
                algorithm: alg.clone(),
                nodes: self.cfg.cluster.nodes,
                image_count: n_images,
                sim_seconds,
                wall_seconds,
                compute_seconds: stage.compute_seconds,
                io_seconds: stage.io_seconds,
                images,
                counters: counters.clone(),
            });
        }
        Ok(reports)
    }

    /// Extract one image: tile it, run the executor once per tile (all
    /// algorithms fused), merge per algorithm.  Returns one
    /// [`MapOutput`] per algorithm, in spec order.
    fn map_one_image(
        &self,
        image_id: u64,
        image: &Rgba8Image,
        handle: &TaskHandle,
    ) -> Result<(Option<Vec<MapOutput>>, u64)> {
        let spec = &self.spec;
        let n = spec.algorithms.len();
        let alg_names: Vec<&str> = spec.algorithms.iter().map(|s| s.as_str()).collect();
        let keeps: Vec<usize> = spec
            .per_image_caps
            .iter()
            .map(|&cap| mapper_retention(cap, spec.report_keypoints))
            .collect();
        let mut raw_count = vec![0u64; n];
        let mut descriptor_count = vec![0u64; n];
        let mut keypoints: Vec<Vec<features::Keypoint>> = vec![Vec::new(); n];
        // Descriptor rows parallel to `keypoints` (only filled when the
        // spec keeps them; `None` rows make every re-rank a plain sort).
        let mut descriptors: Vec<Descriptors> = vec![Descriptors::None; n];
        let mut compute_ns = 0u64;

        for tile in TileIter::new(image.width, image.height) {
            if handle.cancelled() {
                return Ok((None, compute_ns));
            }
            let buf = extract_tile_f32(image, &tile);
            let t0 = std::time::Instant::now();
            let feats_multi = self.executor.run_tile_multi(&alg_names, &buf, tile.core_local())?;
            let dt = t0.elapsed();
            compute_ns += dt.as_nanos() as u64;
            self.tile_hist.observe(dt.as_secs_f64());
            self.tiles_counter.inc();
            self.tiles.fetch_add(1, Ordering::Relaxed);

            for (i, feats) in feats_multi.into_iter().enumerate() {
                raw_count[i] += feats.count;
                descriptor_count[i] += feats.descriptors.len() as u64;
                if spec.keep_descriptors {
                    // Extractors emit exactly one row per retained
                    // keypoint, in keypoint order, so appending both
                    // keeps row i of the batch describing keypoint i.
                    descriptors[i].append(feats.descriptors)?;
                }
                for kp in feats.keypoints {
                    let (sr, sc) = tile.to_scene(kp.row, kp.col);
                    keypoints[i].push(features::Keypoint {
                        row: sr as i32,
                        col: sc as i32,
                        score: kp.score,
                    });
                }
                // Keep the buffer bounded: re-rank + truncate at 4× over.
                if keypoints[i].len() > keeps[i] * 4 {
                    rank_truncate(&mut keypoints[i], &mut descriptors[i], keeps[i]);
                }
            }
        }

        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let mut kps = std::mem::take(&mut keypoints[i]);
            let mut descs = std::mem::take(&mut descriptors[i]);
            rank_truncate(&mut kps, &mut descs, keeps[i]);
            out.push(MapOutput {
                image_id,
                raw_count: raw_count[i],
                keypoints: kps,
                descriptor_count: descriptor_count[i],
                descriptors: descs,
            });
        }
        Ok((Some(out), compute_ns))
    }
}

/// Serialize a mapper output (the record written back to DFS — the
/// paper's mapper step 5 artifact).
fn serialize_output(out: &MapOutput) -> Vec<u8> {
    use byteorder::{ByteOrder, LittleEndian as LE};
    let mut buf = Vec::with_capacity(16 + out.keypoints.len() * 12);
    let mut u64b = [0u8; 8];
    LE::write_u64(&mut u64b, out.image_id);
    buf.extend_from_slice(&u64b);
    LE::write_u64(&mut u64b, out.raw_count);
    buf.extend_from_slice(&u64b);
    let mut u32b = [0u8; 4];
    LE::write_u32(&mut u32b, out.keypoints.len() as u32);
    buf.extend_from_slice(&u32b);
    for kp in &out.keypoints {
        LE::write_u32(&mut u32b, kp.row as u32);
        buf.extend_from_slice(&u32b);
        LE::write_u32(&mut u32b, kp.col as u32);
        buf.extend_from_slice(&u32b);
        LE::write_u32(&mut u32b, kp.score.to_bits());
        buf.extend_from_slice(&u32b);
    }
    buf
}

impl DagStage for ExtractStage<'_> {
    fn name(&self) -> &'static str {
        "extract"
    }

    /// Plan: read the bundle index, compute record-aligned splits
    /// (jobtracker-side planning; its I/O is part of the modeled
    /// startup, as it always was).
    fn plan(&self) -> Result<StagePlan> {
        let (bundle_bytes, _) = self.dfs.read_file(&self.spec.bundle_path, NodeId(0))?;
        let reader = BundleReader::open(&bundle_bytes)?;
        let metas: Vec<RecordMeta> = reader.metas().to_vec();
        // HIPI semantics (paper §3): one mapper per image.  A 1-byte
        // split target makes every record its own split; block-sized
        // splits are the plain-Hadoop alternative.
        let split_target = if self.cfg.scheduler.split_per_image {
            1
        } else {
            self.cfg.storage.block_size as u64
        };
        let splits = hib::splits(&reader, split_target);
        let mut tasks = Vec::with_capacity(splits.len());
        let mut image_unit = BTreeMap::new();
        for (i, s) in splits.iter().enumerate() {
            let preferred = self
                .dfs
                .locate_range(&self.spec.bundle_path, s.byte_start, s.byte_end)
                .unwrap_or_default();
            for rec in s.first_record..s.last_record {
                image_unit.insert(metas[rec].image_id, i);
            }
            tasks.push(TaskDescriptor {
                task_id: i,
                first_record: s.first_record,
                last_record: s.last_record,
                byte_start: s.byte_start,
                byte_end: s.byte_end,
                preferred_nodes: preferred,
            });
        }
        let units = tasks
            .iter()
            .map(|t| UnitSpec {
                deps: Vec::new(),
                preferred_nodes: t.preferred_nodes.clone(),
            })
            .collect();
        *self.unit_censuses.lock().unwrap() = vec![None; tasks.len()];
        *self.planned.lock().unwrap() = Some(Arc::new(ExtractPlanInfo {
            tasks,
            metas,
            image_unit,
        }));
        Ok(StagePlan { units, plan_io_secs: 0.0 })
    }

    /// The mapper body: split read → record decode → tile loop →
    /// per-image census merge (→ feature-file publish).  Input I/O
    /// happens ONCE regardless of how many algorithms are fused.
    fn run_unit(
        &self,
        unit: usize,
        handle: &TaskHandle,
        node: NodeId,
    ) -> Result<Option<UnitOutput>> {
        injected_failure(self.hooks, "task", unit, handle)?;
        let info = self.plan_info();
        let desc = &info.tasks[unit];
        let spec = &self.spec;

        let mut io_secs = 0.0f64;
        let mut compute_ns = 0u64;

        // --- input: read this split's byte range from DFS ------------------
        let (bytes, stats) =
            self.dfs
                .read_range(&spec.bundle_path, desc.byte_start, desc.byte_end, node)?;
        io_secs += self.cost.split_input(stats.local_bytes, stats.remote_bytes);

        let mut outputs: Vec<Vec<MapOutput>> =
            vec![Vec::with_capacity(desc.last_record - desc.first_record); spec.algorithms.len()];
        let total_records = (desc.last_record - desc.first_record).max(1);

        for (done, rec) in (desc.first_record..desc.last_record).enumerate() {
            if handle.cancelled() {
                return Ok(None);
            }
            let rec_off = (info.metas[rec].offset - desc.byte_start) as usize;
            let (image_id, image, _) = hib::decode_record(&bytes[rec_off..])?;

            let (map_out, tile_compute_ns) = self.map_one_image(image_id, &image, handle)?;
            let Some(map_out) = map_out else {
                return Ok(None); // cancelled mid-image
            };
            compute_ns += tile_compute_ns;

            // --- output: the paper's mapper step 5 writes the annotated
            // image back to HDFS, once per algorithm.  We store the
            // keypoint summary (real bytes) and model the cost of the
            // image-sized write the paper performs.
            if spec.write_output {
                for (alg, out) in spec.algorithms.iter().zip(&map_out) {
                    let summary = serialize_output(out);
                    let out_path = format!("{}.out/{alg}/{image_id}", spec.bundle_path);
                    self.dfs.write_file(&out_path, &summary, node)?;
                    io_secs += self
                        .cost
                        .hdfs_write(image.byte_len() as u64, self.cfg.cluster.replication);
                }
            }
            for (dst, out) in outputs.iter_mut().zip(map_out) {
                dst.push(out);
            }
            handle.report_progress((done + 1) as f64 / total_records as f64);
        }

        // --- merge tiles into per-image censuses, one list per algorithm.
        // (Each image lives in exactly one split, so the per-unit merge IS
        // the whole shuffle for these images; the caps and retention are
        // identical to the old job-wide merge.)
        let mut censuses: Vec<Vec<ImageCensus>> = Vec::with_capacity(spec.algorithms.len());
        for (i, alg_outputs) in outputs.into_iter().enumerate() {
            censuses.push(shuffle::merge_image_outputs(
                alg_outputs,
                spec.per_image_caps[i],
                spec.report_keypoints,
            ));
        }

        // --- publish: shuffle each image's features into DFS so a
        // downstream pair unit can start the moment both its scenes'
        // files exist.  Bit-identical across attempts, so a retry or a
        // losing twin rewriting the same path is harmless.
        if let Some((dir, alg_index)) = &self.publish {
            for census in &censuses[*alg_index] {
                let bytes = shuffle::encode_features(census);
                self.dfs.write_file(
                    &feature_path(dir, &spec.algorithms[*alg_index], census.image_id),
                    &bytes,
                    node,
                )?;
                io_secs += self
                    .cost
                    .hdfs_write(bytes.len() as u64, self.cfg.cluster.replication);
            }
        }

        Ok(Some(UnitOutput {
            payload: Box::new(censuses),
            compute_ns,
            io_secs,
        }))
    }

    fn merge(&self, unit: usize, payload: Box<dyn Any + Send>) -> Result<()> {
        // Downcast BEFORE taking any stage lock: the coordinator calls
        // merge() between slot completions, so work done under the lock
        // serializes them.
        let censuses = payload
            .downcast::<Vec<Vec<ImageCensus>>>()
            .map_err(|_| DifetError::Job("extract stage: payload type mismatch".into()))?;
        if self.defer {
            // O(1): park the payload for the downstream tree merge.
            self.unit_censuses.lock().unwrap()[unit] = Some(Arc::new(*censuses));
            return Ok(());
        }
        let mut sink = self.censuses.lock().unwrap();
        for (alg_index, list) in censuses.into_iter().enumerate() {
            for census in list {
                sink.insert((census.image_id, alg_index), census);
            }
        }
        Ok(())
    }

    fn finalize(&self) -> Result<()> {
        if self.defer {
            // The fold happens downstream; only check unit coverage here.
            if self.unit_censuses.lock().unwrap().iter().any(|s| s.is_none()) {
                return Err(DifetError::Job("extract unit lost its censuses".into()));
            }
            return Ok(());
        }
        let n_images = self.plan_info().metas.len();
        let merged = self.censuses.lock().unwrap().len();
        if merged != n_images * self.spec.algorithms.len() {
            return Err(DifetError::Job(format!(
                "extract stage merged {merged} censuses, expected {}",
                n_images * self.spec.algorithms.len()
            )));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Register: the reduce-shaped scene-pair stage.
// ---------------------------------------------------------------------------

/// Where a [`PairStage`] gets its per-scene features from.
pub enum PairSource<'a> {
    /// Censuses known up front (the standalone registration job): the
    /// stage plan shuffles the feature files into DFS itself.
    Censuses(&'a [ImageCensus]),
    /// An upstream [`ExtractStage`] (at DAG index `stage_index`) that
    /// publishes feature files from its map units; pair units then
    /// depend on exactly the extract units owning their two scenes.
    Extract {
        stage: &'a ExtractStage<'a>,
        stage_index: usize,
    },
}

/// Reduce-shaped pair registration: ratio-test matching + translation
/// RANSAC per scene pair, with per-pair seeds ([`pair_seed`]) so results
/// never depend on which node/slot/attempt ran the pair.
pub struct PairStage<'a> {
    cfg: &'a Config,
    dfs: &'a Dfs,
    spec: RegistrationSpec,
    hooks: &'a JobHooks,
    cost: CostModel,
    source: PairSource<'a>,
    pairs_counter: Arc<Counter>,
    pair_hist: Arc<Histogram>,
    planned: Mutex<Option<Arc<Vec<PairTask>>>>,
    scene_ids: Mutex<Vec<u64>>,
    results: Mutex<Vec<Option<PairResult>>>,
}

impl<'a> PairStage<'a> {
    pub fn new(
        cfg: &'a Config,
        dfs: &'a Dfs,
        spec: RegistrationSpec,
        source: PairSource<'a>,
        registry: &Registry,
        hooks: &'a JobHooks,
    ) -> Self {
        PairStage {
            cfg,
            dfs,
            spec,
            hooks,
            cost: CostModel::new(&cfg.cluster),
            source,
            pairs_counter: registry.counter("pairs_processed"),
            pair_hist: registry.histogram("pair_latency"),
            planned: Mutex::new(None),
            scene_ids: Mutex::new(Vec::new()),
            results: Mutex::new(Vec::new()),
        }
    }

    fn plan_info(&self) -> Arc<Vec<PairTask>> {
        self.planned
            .lock()
            .unwrap()
            .clone()
            .expect("pair stage used before plan")
    }

    /// All scene ids the stage planned over (alignment needs them).
    pub fn scene_ids(&self) -> Vec<u64> {
        self.scene_ids.lock().unwrap().clone()
    }

    /// Pair results in pair-id order (valid after the stage completed).
    pub fn results(&self) -> Result<Vec<PairResult>> {
        self.results
            .lock()
            .unwrap()
            .clone()
            .into_iter()
            .collect::<Option<Vec<_>>>()
            .ok_or_else(|| DifetError::Job("registration pair lost its result".into()))
    }

    /// Planned unit count (valid after plan).
    pub fn unit_count(&self) -> usize {
        self.plan_info().len()
    }

    /// One unit's result (valid once the unit merged — i.e. from a
    /// downstream unit that declared it as a dep).
    pub fn result_of(&self, unit: usize) -> Result<PairResult> {
        self.results.lock().unwrap()[unit]
            .clone()
            .ok_or_else(|| DifetError::Job(format!("pair unit {unit} has not merged yet")))
    }

    /// A unit's preferred nodes (locality hint for downstream merges).
    pub fn unit_preferred(&self, unit: usize) -> Vec<NodeId> {
        self.plan_info()[unit].preferred_nodes.clone()
    }

    /// Assemble the [`RegistrationReport`] from this stage's slice of a
    /// finished DAG run.
    pub fn report(
        &self,
        stage: &StageReport,
        sim_seconds: f64,
        wall_seconds: f64,
    ) -> Result<RegistrationReport> {
        let pairs = self.results()?;
        let mut counters = stage.scheduler_counters();
        counters.insert("pairs".into(), pairs.len() as u64);
        counters.insert(
            "registered_pairs".into(),
            pairs.iter().filter(|p| p.translation.is_some()).count() as u64,
        );
        Ok(RegistrationReport {
            algorithm: self.spec.algorithm.clone(),
            nodes: self.cfg.cluster.nodes,
            pair_count: pairs.len(),
            sim_seconds,
            wall_seconds,
            compute_seconds: stage.compute_seconds,
            io_seconds: stage.io_seconds,
            pairs,
            counters,
        })
    }
}

impl DagStage for PairStage<'_> {
    fn name(&self) -> &'static str {
        "register"
    }

    fn gates(&self) -> Vec<Gate> {
        match &self.source {
            PairSource::Censuses(_) => Vec::new(),
            // Pairs are plannable as soon as the bundle index (scene ids
            // + unit ownership) exists — before any extraction ran.
            PairSource::Extract { stage_index, .. } => vec![Gate::Planned(*stage_index)],
        }
    }

    fn plan(&self) -> Result<StagePlan> {
        let spec = &self.spec;
        let fpath = |id: u64| feature_path(&spec.feature_dir, &spec.algorithm, id);

        let scene_ids = match &self.source {
            PairSource::Censuses(censuses) => {
                censuses.iter().map(|c| c.image_id).collect::<Vec<u64>>()
            }
            PairSource::Extract { stage, .. } => stage.scene_ids(),
        };
        let pairs = shuffle::enumerate_pairs(&scene_ids, spec.pairs.as_deref())?;

        // Source-dependent feature-file shuffle (Censuses mode only:
        // with an upstream extract stage, the map units publish).
        let plan_io_secs = match &self.source {
            PairSource::Censuses(censuses) => {
                let by_id: BTreeMap<u64, &ImageCensus> =
                    censuses.iter().map(|c| (c.image_id, c)).collect();
                if by_id.len() != censuses.len() {
                    return Err(DifetError::Job("duplicate image ids in census set".into()));
                }
                // Shuffle: write each referenced scene's features into DFS
                // (the payloads the paper-shaped map stage would have left
                // behind), round-robin like reducer partitions; the stage
                // opens after the slowest writer.
                let mut needed: Vec<u64> = pairs.iter().flat_map(|&(a, b)| [a, b]).collect();
                needed.sort_unstable();
                needed.dedup();
                let mut write_secs = vec![0.0f64; self.cfg.cluster.nodes];
                for &id in &needed {
                    let bytes = shuffle::encode_features(by_id[&id]);
                    let writer = NodeId(id as usize % self.cfg.cluster.nodes);
                    self.dfs.write_file(&fpath(id), &bytes, writer)?;
                    write_secs[writer.0] +=
                        self.cost.hdfs_write(bytes.len() as u64, self.cfg.cluster.replication);
                }
                write_secs.iter().cloned().fold(0.0, f64::max)
            }
            PairSource::Extract { .. } => 0.0,
        };

        let mut tasks = Vec::with_capacity(pairs.len());
        let mut units = Vec::with_capacity(pairs.len());
        for (pair_id, &(a, b)) in pairs.iter().enumerate() {
            let (path_a, path_b) = (fpath(a), fpath(b));
            let (preferred, deps) = match &self.source {
                PairSource::Censuses(_) => {
                    // Files exist already: locality toward their replicas.
                    (
                        preferred_for_paths(self.dfs, &[path_a.clone(), path_b.clone()]),
                        Vec::new(),
                    )
                }
                PairSource::Extract { stage, stage_index } => {
                    // Files appear when the owning extract units merge;
                    // those units are this pair's inputs, and their
                    // splits' replica nodes are where the published
                    // feature files most likely land (the map unit runs
                    // data-local when it can and writes from its node).
                    let mut deps = Vec::new();
                    let mut preferred = Vec::new();
                    for id in [a, b] {
                        let unit = stage.unit_of_image(id).ok_or_else(|| {
                            DifetError::Job(format!("pair references unknown scene {id}"))
                        })?;
                        let r = UnitRef { stage: *stage_index, unit };
                        if !deps.contains(&r) {
                            deps.push(r);
                        }
                        for n in stage.unit_preferred(unit) {
                            if !preferred.contains(&n) {
                                preferred.push(n);
                            }
                        }
                    }
                    (preferred, deps)
                }
            };
            tasks.push(PairTask {
                pair_id,
                image_a: a,
                image_b: b,
                path_a,
                path_b,
                preferred_nodes: preferred.clone(),
            });
            units.push(UnitSpec { deps, preferred_nodes: preferred });
        }
        *self.results.lock().unwrap() = vec![None; tasks.len()];
        *self.scene_ids.lock().unwrap() = scene_ids;
        *self.planned.lock().unwrap() = Some(Arc::new(tasks));
        Ok(StagePlan { units, plan_io_secs })
    }

    /// The reducer body: fetch both feature files, match descriptors
    /// (chunked, honouring cancellation so a losing speculative twin
    /// dies mid-scan), then RANSAC the translation.
    fn run_unit(
        &self,
        unit: usize,
        handle: &TaskHandle,
        node: NodeId,
    ) -> Result<Option<UnitOutput>> {
        injected_failure(self.hooks, "pair", unit, handle)?;
        let tasks = self.plan_info();
        let task = &tasks[unit];
        let spec = &self.spec;

        // --- shuffle input: fetch both scenes' features --------------------
        let (bytes_a, stats_a) = self.dfs.read_file(&task.path_a, node)?;
        let (bytes_b, stats_b) = self.dfs.read_file(&task.path_b, node)?;
        let io_secs = self.cost.split_input(
            stats_a.local_bytes + stats_b.local_bytes,
            stats_a.remote_bytes + stats_b.remote_bytes,
        );
        let (id_a, kps_a, desc_a) = shuffle::decode_features(&bytes_a)?;
        let (id_b, kps_b, desc_b) = shuffle::decode_features(&bytes_b)?;
        if (id_a, id_b) != (task.image_a, task.image_b) {
            return Err(DifetError::Job(format!(
                "feature file routing mixup: wanted ({}, {}), got ({id_a}, {id_b})",
                task.image_a, task.image_b
            )));
        }

        // --- reduce: match + register --------------------------------------
        let t0 = std::time::Instant::now();
        const MATCH_CHUNK: usize = 64;
        let Some(matches) = match_descriptors_while(
            &desc_a,
            &desc_b,
            spec.ratio,
            MATCH_CHUNK,
            &mut |done, total| {
                handle.report_progress(done as f64 / total.max(1) as f64);
                !handle.cancelled()
            },
        ) else {
            return Ok(None); // cancelled: the twin won
        };
        if handle.cancelled() {
            return Ok(None);
        }
        let translation = if matches.len() >= spec.min_matches {
            ransac_translation(
                &kps_a,
                &kps_b,
                &matches,
                spec.tolerance_px,
                spec.ransac_iters,
                pair_seed(spec.seed, task.image_a, task.image_b),
            )
        } else {
            None
        };
        let compute_ns = t0.elapsed().as_nanos() as u64;
        self.pair_hist.observe(compute_ns as f64 * 1e-9);

        Ok(Some(UnitOutput {
            payload: Box::new(PairResult {
                image_a: task.image_a,
                image_b: task.image_b,
                matches: matches.len(),
                translation,
            }),
            compute_ns,
            io_secs,
        }))
    }

    fn merge(&self, unit: usize, payload: Box<dyn Any + Send>) -> Result<()> {
        let result = payload
            .downcast::<PairResult>()
            .map_err(|_| DifetError::Job("pair stage: payload type mismatch".into()))?;
        self.pairs_counter.inc();
        self.results.lock().unwrap()[unit] = Some(*result);
        Ok(())
    }

    fn finalize(&self) -> Result<()> {
        self.results().map(|_| ())
    }
}

// ---------------------------------------------------------------------------
// Align: the least-squares solve, sharded per connected component.
// ---------------------------------------------------------------------------

/// Where an [`AlignStage`] gets its registered pair results from.
pub enum PairResultsSource<'a> {
    /// Directly from a completed [`PairStage`] at DAG index `stage_index`.
    Stage {
        stage: &'a PairStage<'a>,
        stage_index: usize,
    },
    /// From a tree-merged registration result set
    /// ([`super::merge::TreeMergeStage`] over a [`PairTreeReducer`]) at
    /// DAG index `stage_index`; `pairs` still supplies the scene-id set.
    Merged {
        pairs: &'a PairStage<'a>,
        merge: &'a super::merge::TreeMergeStage<'a, super::merge::PairTreeReducer<'a>>,
        stage_index: usize,
    },
}

/// Alignment over a completed pair set, sharded one unit per connected
/// component of the measurement graph.  Components are independent
/// linear systems ([`crate::mosaic::AlignProblem`]), so the shards can
/// run on any node in any order and assemble to exactly the serial
/// [`crate::mosaic::solve_alignment`] result — the gate still waits for
/// the FULL pair set, because the component structure itself is a global
/// function of every measurement.
pub struct AlignStage<'a> {
    source: PairResultsSource<'a>,
    hooks: &'a JobHooks,
    options: crate::mosaic::AlignOptions,
    problem: Mutex<Option<Arc<crate::mosaic::AlignProblem>>>,
    solutions: Mutex<Vec<Option<crate::mosaic::ComponentSolution>>>,
    solved: Mutex<Option<GlobalAlignment>>,
}

impl<'a> AlignStage<'a> {
    pub fn new(pairs: &'a PairStage<'a>, pair_stage_index: usize, hooks: &'a JobHooks) -> Self {
        Self::from_source(
            PairResultsSource::Stage { stage: pairs, stage_index: pair_stage_index },
            hooks,
        )
    }

    pub fn from_source(source: PairResultsSource<'a>, hooks: &'a JobHooks) -> Self {
        AlignStage {
            source,
            hooks,
            options: crate::mosaic::AlignOptions::default(),
            problem: Mutex::new(None),
            solutions: Mutex::new(Vec::new()),
            solved: Mutex::new(None),
        }
    }

    fn problem(&self) -> Arc<crate::mosaic::AlignProblem> {
        self.problem
            .lock()
            .unwrap()
            .clone()
            .expect("align stage used before plan")
    }

    /// The solved alignment (valid after the stage completed).
    pub fn alignment(&self) -> Result<GlobalAlignment> {
        self.solved
            .lock()
            .unwrap()
            .clone()
            .ok_or_else(|| DifetError::Job("align stage read before completion".into()))
    }
}

impl DagStage for AlignStage<'_> {
    fn name(&self) -> &'static str {
        "align"
    }

    fn gates(&self) -> Vec<Gate> {
        match &self.source {
            PairResultsSource::Stage { stage_index, .. }
            | PairResultsSource::Merged { stage_index, .. } => {
                vec![Gate::Completed(*stage_index)]
            }
        }
    }

    /// Plan: build the measurement graph and its connected components
    /// (jobtracker-side, cheap), one unit per component.
    fn plan(&self) -> Result<StagePlan> {
        let results = match &self.source {
            PairResultsSource::Stage { stage, .. } => stage.results()?,
            PairResultsSource::Merged { merge, .. } => merge.reducer().results()?,
        };
        let measurements = crate::mosaic::measurements_from_pairs(&results);
        if measurements.is_empty() {
            return Err(DifetError::Job(
                "stitch: no scene pair registered; nothing to align".into(),
            ));
        }
        let scene_ids = match &self.source {
            PairResultsSource::Stage { stage, .. } => stage.scene_ids(),
            PairResultsSource::Merged { pairs, .. } => pairs.scene_ids(),
        };
        let problem = crate::mosaic::prepare_alignment(&scene_ids, &measurements, self.options)?;
        let units = (0..problem.num_components())
            .map(|_| UnitSpec::default())
            .collect();
        *self.solutions.lock().unwrap() = vec![None; problem.num_components()];
        *self.problem.lock().unwrap() = Some(Arc::new(problem));
        Ok(StagePlan { units, plan_io_secs: 0.0 })
    }

    fn run_unit(
        &self,
        unit: usize,
        handle: &TaskHandle,
        _node: NodeId,
    ) -> Result<Option<UnitOutput>> {
        injected_failure(self.hooks, "align", unit, handle)?;
        let problem = self.problem();
        let t0 = std::time::Instant::now();
        let solution = problem.solve_component(unit);
        let compute_ns = t0.elapsed().as_nanos() as u64;
        if handle.cancelled() {
            return Ok(None);
        }
        Ok(Some(UnitOutput {
            payload: Box::new(solution),
            compute_ns,
            io_secs: 0.0,
        }))
    }

    fn merge(&self, unit: usize, payload: Box<dyn Any + Send>) -> Result<()> {
        let solution = payload
            .downcast::<crate::mosaic::ComponentSolution>()
            .map_err(|_| DifetError::Job("align stage: payload type mismatch".into()))?;
        self.solutions.lock().unwrap()[unit] = Some(*solution);
        Ok(())
    }

    fn finalize(&self) -> Result<()> {
        let solutions: Vec<crate::mosaic::ComponentSolution> = self
            .solutions
            .lock()
            .unwrap()
            .clone()
            .into_iter()
            .collect::<Option<Vec<_>>>()
            .ok_or_else(|| DifetError::Job("alignment component lost its solution".into()))?;
        let alignment = self.problem().assemble(&solutions)?;
        *self.solved.lock().unwrap() = Some(alignment);
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Composite: canvas-tile compositing.
// ---------------------------------------------------------------------------

/// Where a [`CompositeStage`] gets its alignment from.
pub enum AlignSource<'a> {
    /// Solved elsewhere (the standalone mosaic job).
    Given(&'a GlobalAlignment),
    /// An upstream [`AlignStage`] at DAG index `stage_index`.
    Solved {
        stage: &'a AlignStage<'a>,
        stage_index: usize,
    },
}

/// Where a [`CompositeStage`] gets its decoded scenes from.
pub enum SceneSource<'a> {
    /// Scenes decoded up front by the caller (the standalone mosaic job).
    Given(&'a [(u64, Rgba8Image)]),
    /// An upstream [`IngestStage`] at DAG index `stage_index`; the plan
    /// gate waits for it, then borrows its decoded scenes without a copy.
    Ingested {
        stage: &'a IngestStage<'a>,
        stage_index: usize,
    },
}

struct CompositePlanInfo {
    canvas: Canvas,
    alignment: GlobalAlignment,
    tasks: Vec<CanvasTile>,
    /// The scene set the plan was built over (given or ingested).
    scenes: Arc<Vec<(u64, Rgba8Image)>>,
}

/// Canvas-tile compositing: scenes are shuffled into CRC-guarded DFS
/// files at plan time, the canvas splits into tile-shaped units, and
/// every canvas pixel is a pure function of the scenes covering it (the
/// blend accumulates in ascending scene-id order) — byte-identical to
/// [`crate::mosaic::composite_sequential`] under any schedule.
pub struct CompositeStage<'a> {
    cfg: &'a Config,
    dfs: &'a Dfs,
    hooks: &'a JobHooks,
    cost: CostModel,
    scenes: SceneSource<'a>,
    spec: MosaicSpec,
    align: AlignSource<'a>,
    tiles_counter: Arc<Counter>,
    tile_hist: Arc<Histogram>,
    rms_hist: Arc<Histogram>,
    residual_gauge: Arc<Gauge>,
    planned: Mutex<Option<Arc<CompositePlanInfo>>>,
    mosaic: Mutex<Option<Rgba8Image>>,
    overlaps: Mutex<Vec<OverlapStat>>,
}

impl<'a> CompositeStage<'a> {
    pub fn new(
        cfg: &'a Config,
        dfs: &'a Dfs,
        scenes: SceneSource<'a>,
        align: AlignSource<'a>,
        spec: MosaicSpec,
        registry: &Registry,
        hooks: &'a JobHooks,
    ) -> Self {
        CompositeStage {
            cfg,
            dfs,
            hooks,
            cost: CostModel::new(&cfg.cluster),
            scenes,
            spec,
            align,
            tiles_counter: registry.counter("canvas_tiles"),
            tile_hist: registry.histogram("canvas_tile_latency"),
            rms_hist: registry.histogram("overlap_rms"),
            residual_gauge: registry.gauge("mosaic_max_cycle_residual"),
            planned: Mutex::new(None),
            mosaic: Mutex::new(None),
            overlaps: Mutex::new(Vec::new()),
        }
    }

    fn plan_info(&self) -> Arc<CompositePlanInfo> {
        self.planned
            .lock()
            .unwrap()
            .clone()
            .expect("composite stage used before plan")
    }

    /// Canvas geometry + tile rects (downstream band deps), post-plan.
    pub fn planned_tiles(&self) -> (usize, usize, Vec<[usize; 4]>) {
        let info = self.plan_info();
        (
            info.canvas.width,
            info.canvas.height,
            info.tasks.iter().map(|t| t.rect).collect(),
        )
    }

    /// Copy rows `[r0, r1)` of the composited canvas (valid once every
    /// tile intersecting those rows has merged — i.e. from a downstream
    /// unit that declared them as deps).
    pub fn canvas_rows(&self, r0: usize, r1: usize) -> Result<Rgba8Image> {
        let guard = self.mosaic.lock().unwrap();
        let mosaic = guard
            .as_ref()
            .ok_or_else(|| DifetError::Job("composite canvas read before plan".into()))?;
        let w = mosaic.width;
        Ok(Rgba8Image {
            width: w,
            height: r1 - r0,
            data: mosaic.data[r0 * w * 4..r1 * w * 4].to_vec(),
        })
    }

    /// The finished mosaic (valid after the stage completed).
    pub fn mosaic(&self) -> Result<Rgba8Image> {
        self.mosaic
            .lock()
            .unwrap()
            .clone()
            .ok_or_else(|| DifetError::Job("composite stage read before completion".into()))
    }

    /// The alignment the plan actually used (given or solved upstream).
    pub fn alignment_used(&self) -> GlobalAlignment {
        self.plan_info().alignment.clone()
    }

    /// Assemble the [`MosaicReport`] from this stage's slice of a
    /// finished DAG run.
    pub fn report(
        &self,
        stage: &StageReport,
        sim_seconds: f64,
        wall_seconds: f64,
    ) -> MosaicReport {
        let info = self.plan_info();
        let overlaps = self.overlaps.lock().unwrap().clone();
        let mut counters = stage.scheduler_counters();
        counters.insert("tiles".into(), info.tasks.len() as u64);
        counters.insert("scenes".into(), info.scenes.len() as u64);
        counters.insert("overlaps".into(), overlaps.len() as u64);
        MosaicReport {
            nodes: self.cfg.cluster.nodes,
            scene_count: info.scenes.len(),
            canvas_width: info.canvas.width,
            canvas_height: info.canvas.height,
            tile_count: info.tasks.len(),
            blend: self.spec.blend,
            sim_seconds,
            wall_seconds,
            compute_seconds: stage.compute_seconds,
            io_seconds: stage.io_seconds,
            overlaps,
            max_cycle_residual: info.alignment.max_residual(),
            rms_cycle_residual: info.alignment.rms_residual(),
            counters,
        }
    }
}

impl DagStage for CompositeStage<'_> {
    fn name(&self) -> &'static str {
        "composite"
    }

    fn gates(&self) -> Vec<Gate> {
        let mut gates = Vec::new();
        if let AlignSource::Solved { stage_index, .. } = &self.align {
            gates.push(Gate::Completed(*stage_index));
        }
        if let SceneSource::Ingested { stage_index, .. } = &self.scenes {
            gates.push(Gate::Completed(*stage_index));
        }
        gates
    }

    /// Plan: solved positions → integer canvas layout, scene shuffle
    /// into DFS (round-robin, like reducer partitions), one unit per
    /// canvas tile with locality toward the overlapping scene files.
    fn plan(&self) -> Result<StagePlan> {
        let scenes: Arc<Vec<(u64, Rgba8Image)>> = match &self.scenes {
            SceneSource::Given(s) => Arc::new(s.to_vec()),
            SceneSource::Ingested { stage, .. } => stage.scenes()?,
        };
        let alignment = match &self.align {
            AlignSource::Given(a) => (*a).clone(),
            AlignSource::Solved { stage, .. } => stage.alignment()?,
        };
        let dims: Vec<(u64, usize, usize)> = scenes
            .iter()
            .map(|(id, img)| (*id, img.width, img.height))
            .collect();
        // (layout rejects duplicate scene ids, so path routing is lossless.)
        let canvas = crate::mosaic::layout(&alignment, &dims)?;

        let scene_codec = if self.cfg.storage.compress {
            crate::hib::Codec::Deflate
        } else {
            crate::hib::Codec::Raw
        };
        let scene_path = |id: u64| format!("{}/{id}", self.spec.scene_dir);
        let mut write_secs = vec![0.0f64; self.cfg.cluster.nodes];
        for (id, img) in scenes.iter() {
            let bytes = shuffle::encode_scene(
                *id,
                img,
                scene_codec,
                self.cfg.storage.compression_level,
            )?;
            let writer = NodeId(*id as usize % self.cfg.cluster.nodes);
            self.dfs.write_file(&scene_path(*id), &bytes, writer)?;
            write_secs[writer.0] +=
                self.cost.hdfs_write(bytes.len() as u64, self.cfg.cluster.replication);
        }
        let plan_io_secs = write_secs.iter().cloned().fold(0.0, f64::max);

        let tasks: Vec<CanvasTile> = crate::mosaic::tile_rects(&canvas, self.spec.canvas_tile)
            .into_iter()
            .enumerate()
            .map(|(tile_id, rect)| {
                let scene_ids = crate::mosaic::scenes_in_rect(&canvas, rect);
                let scene_paths: Vec<String> =
                    scene_ids.iter().map(|&id| scene_path(id)).collect();
                let preferred = preferred_for_paths(self.dfs, &scene_paths);
                CanvasTile { tile_id, rect, scene_ids, scene_paths, preferred_nodes: preferred }
            })
            .collect();
        let units = tasks
            .iter()
            .map(|t| UnitSpec {
                deps: Vec::new(),
                preferred_nodes: t.preferred_nodes.clone(),
            })
            .collect();
        *self.mosaic.lock().unwrap() = Some(Rgba8Image::new(canvas.width, canvas.height));
        *self.planned.lock().unwrap() =
            Some(Arc::new(CompositePlanInfo { canvas, alignment, tasks, scenes }));
        Ok(StagePlan { units, plan_io_secs })
    }

    /// The tile body: fetch the scenes overlapping this canvas tile from
    /// DFS, decode them (CRC-guarded), composite the rect with row-level
    /// progress and cooperative cancellation.
    fn run_unit(
        &self,
        unit: usize,
        handle: &TaskHandle,
        node: NodeId,
    ) -> Result<Option<UnitOutput>> {
        injected_failure(self.hooks, "tile", unit, handle)?;
        let info = self.plan_info();
        let task = &info.tasks[unit];

        // --- shuffle input: fetch only the scenes overlapping this rect ----
        let mut io_secs = 0.0f64;
        let mut tile_scenes: Vec<(u64, Rgba8Image)> = Vec::with_capacity(task.scene_paths.len());
        for (expected_id, path) in task.scene_ids.iter().zip(&task.scene_paths) {
            if handle.cancelled() {
                return Ok(None);
            }
            let (bytes, stats) = self.dfs.read_file(path, node)?;
            io_secs += self.cost.split_input(stats.local_bytes, stats.remote_bytes);
            let (id, img) = shuffle::decode_scene(&bytes)?;
            if id != *expected_id {
                return Err(DifetError::Job(format!(
                    "scene file routing mixup: wanted {expected_id}, got {id}"
                )));
            }
            tile_scenes.push((id, img));
        }
        let by_id: BTreeMap<u64, &Rgba8Image> =
            tile_scenes.iter().map(|(id, img)| (*id, img)).collect();

        // --- reduce: composite the rect ------------------------------------
        let t0 = std::time::Instant::now();
        let Some(pixels) = crate::mosaic::composite_rect_while(
            &info.canvas,
            &by_id,
            self.spec.blend,
            task.rect,
            &mut |done, total| {
                handle.report_progress(done as f64 / total.max(1) as f64);
                !handle.cancelled()
            },
        )?
        else {
            return Ok(None); // cancelled: the twin won
        };
        let compute_ns = t0.elapsed().as_nanos() as u64;
        self.tile_hist.observe(compute_ns as f64 * 1e-9);

        Ok(Some(UnitOutput {
            payload: Box::new(pixels),
            compute_ns,
            io_secs,
        }))
    }

    fn merge(&self, unit: usize, payload: Box<dyn Any + Send>) -> Result<()> {
        let pixels = payload
            .downcast::<Vec<u8>>()
            .map_err(|_| DifetError::Job("composite stage: payload type mismatch".into()))?;
        let info = self.plan_info();
        let [r0, r1, c0, c1] = info.tasks[unit].rect;
        let mut guard = self.mosaic.lock().unwrap();
        let mosaic = guard
            .as_mut()
            .ok_or_else(|| DifetError::Job("composite canvas missing at merge".into()))?;
        mosaic.blit(r0, c0, r1 - r0, c1 - c0, &pixels);
        self.tiles_counter.inc();
        Ok(())
    }

    /// Seam diagnostics once the whole canvas exists.
    fn finalize(&self) -> Result<()> {
        let info = self.plan_info();
        let by_id: BTreeMap<u64, &Rgba8Image> =
            info.scenes.iter().map(|(id, img)| (*id, img)).collect();
        let overlaps = crate::mosaic::overlap_stats(&info.canvas, &by_id)?;
        for o in &overlaps {
            self.rms_hist.observe(o.rms);
        }
        self.residual_gauge.set(info.alignment.max_residual());
        *self.overlaps.lock().unwrap() = overlaps;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Vectorize: band-tile labeling over a mask.
// ---------------------------------------------------------------------------

/// Where a [`LabelStage`] gets its mask from.
pub enum MaskSource<'a> {
    /// A mask known up front (the standalone vector job): the plan
    /// shuffles it into DFS and band units range-read their rows.
    Given(&'a Mask),
    /// An upstream [`CompositeStage`] at DAG index `stage_index`: each
    /// band unit depends on the canvas tiles covering its rows and
    /// thresholds them at `threshold` the moment they are composited.
    Mosaic {
        stage: &'a CompositeStage<'a>,
        stage_index: usize,
        threshold: f32,
    },
}

struct VectorPlanInfo {
    width: usize,
    height: usize,
    tasks: Vec<LabelTile>,
}

/// Band-tile connected-component labeling: tile-local CCL per full-width
/// band, tile labels shuffled back through CRC-guarded DFS files, and a
/// reduce-side union-find merge at finalize — bit-identical to
/// [`crate::vector::label_sequential`] at any node count, band size and
/// schedule (canonical min-pixel component keys).
pub struct LabelStage<'a> {
    cfg: &'a Config,
    dfs: &'a Dfs,
    hooks: &'a JobHooks,
    cost: CostModel,
    spec: VectorSpec,
    source: MaskSource<'a>,
    /// When set, `finalize()` skips the serial coordinator read+merge
    /// loop — a downstream tree of pairwise band merges performs it and
    /// hands the result back via [`LabelStage::install_merged`].
    defer: bool,
    tiles_counter: Arc<Counter>,
    tile_hist: Arc<Histogram>,
    residual_gauge: Arc<Gauge>,
    objects_counter: Arc<Counter>,
    planned: Mutex<Option<Arc<VectorPlanInfo>>>,
    done: Mutex<Vec<bool>>,
    merged: Mutex<Option<(Labels, Vec<ObjectStats>, MergeStats)>>,
}

impl<'a> LabelStage<'a> {
    pub fn new(
        cfg: &'a Config,
        dfs: &'a Dfs,
        spec: VectorSpec,
        source: MaskSource<'a>,
        registry: &Registry,
        hooks: &'a JobHooks,
    ) -> Self {
        LabelStage {
            cfg,
            dfs,
            hooks,
            cost: CostModel::new(&cfg.cluster),
            spec,
            source,
            defer: false,
            tiles_counter: registry.counter("label_tiles"),
            tile_hist: registry.histogram("label_tile_latency"),
            residual_gauge: registry.gauge("vector_max_merge_residual"),
            objects_counter: registry.counter("objects_extracted"),
            planned: Mutex::new(None),
            done: Mutex::new(Vec::new()),
            merged: Mutex::new(None),
        }
    }

    fn plan_info(&self) -> Arc<VectorPlanInfo> {
        self.planned
            .lock()
            .unwrap()
            .clone()
            .expect("vector stage used before plan")
    }

    /// Defer the union-find merge to a downstream tree-merge stage:
    /// `finalize()` only checks coverage, and the merge stage installs
    /// its fold via [`LabelStage::install_merged`].
    pub fn defer_merge(mut self) -> Self {
        self.defer = true;
        self
    }

    /// Planned unit count (valid after plan).
    pub fn unit_count(&self) -> usize {
        self.plan_info().tasks.len()
    }

    /// Mask geometry (valid after plan).
    pub fn dims(&self) -> (usize, usize) {
        let info = self.plan_info();
        (info.width, info.height)
    }

    /// One band unit's shuffled label-file path + expected tile id.
    pub fn unit_labels_file(&self, unit: usize) -> (String, u64) {
        let task = &self.plan_info().tasks[unit];
        (task.labels_path.clone(), task.tile_id as u64)
    }

    /// A unit's preferred nodes (locality hint for downstream merges).
    pub fn unit_preferred(&self, unit: usize) -> Vec<NodeId> {
        self.plan_info().tasks[unit].preferred_nodes.clone()
    }

    /// Install the tree-merged labeling (defer mode) and publish the
    /// same diagnostics the serial finalize recorded.
    pub fn install_merged(&self, merged: (Labels, Vec<ObjectStats>, MergeStats)) -> Result<()> {
        let info = self.plan_info();
        if (merged.0.width, merged.0.height) != (info.width, info.height) {
            return Err(DifetError::Job(format!(
                "label merge produced a {}×{} raster for a {}×{} mask",
                merged.0.height, merged.0.width, info.height, info.width
            )));
        }
        self.residual_gauge.set(merged.2.max_merge_residual() as f64);
        self.objects_counter.add(merged.1.len() as u64);
        *self.merged.lock().unwrap() = Some(merged);
        Ok(())
    }

    /// The merged label raster, object table and merge diagnostics
    /// (valid after the stage completed).
    pub fn output(&self) -> Result<(Labels, Vec<ObjectStats>, MergeStats)> {
        self.merged
            .lock()
            .unwrap()
            .clone()
            .ok_or_else(|| DifetError::Job("vector stage read before completion".into()))
    }

    /// Assemble the [`VectorReport`] from this stage's slice of a
    /// finished DAG run.
    pub fn report(
        &self,
        stage: &StageReport,
        sim_seconds: f64,
        wall_seconds: f64,
    ) -> Result<VectorReport> {
        let info = self.plan_info();
        let (_, objects, mstats) = self.output()?;
        // Object areas partition the foreground exactly, so the stats
        // sum IS the mask census (asserted by the e2e suite).
        let foreground_px: u64 = objects.iter().map(|o| o.area).sum();
        let mut counters = stage.scheduler_counters();
        counters.insert("tiles".into(), info.tasks.len() as u64);
        counters.insert("objects".into(), objects.len() as u64);
        counters.insert("seam_unions".into(), mstats.seam_unions);
        counters.insert("max_merge_residual".into(), mstats.max_merge_residual());
        Ok(VectorReport {
            nodes: self.cfg.cluster.nodes,
            width: info.width,
            height: info.height,
            tile_count: info.tasks.len(),
            object_count: objects.len(),
            foreground_px,
            max_merge_residual: mstats.max_merge_residual(),
            seam_unions: mstats.seam_unions,
            sim_seconds,
            wall_seconds,
            compute_seconds: stage.compute_seconds,
            io_seconds: stage.io_seconds,
            counters,
        })
    }

    /// This band's mask rows: a DFS range read (standalone) or a
    /// threshold over the already-composited canvas rows (mosaic mode).
    /// Both are pure per-pixel functions of the same inputs, so the band
    /// masks are identical to slicing a whole-raster [`Mask`].
    fn band_mask(&self, task: &LabelTile, node: NodeId) -> Result<(Mask, f64)> {
        let [r0, r1, c0, c1] = task.rect;
        let (rows, width) = (r1 - r0, c1 - c0);
        match &self.source {
            MaskSource::Given(_) => {
                let (bytes, stats) =
                    self.dfs
                        .read_range(&task.mask_path, task.byte_start, task.byte_end, node)?;
                let io = self.cost.split_input(stats.local_bytes, stats.remote_bytes);
                if c0 != 0 || bytes.len() != rows * width {
                    return Err(DifetError::Job(format!(
                        "mask band {}: got {} bytes, rect {:?} needs {}",
                        task.tile_id,
                        bytes.len(),
                        task.rect,
                        rows * width
                    )));
                }
                Ok((Mask { width, height: rows, data: bytes }, io))
            }
            MaskSource::Mosaic { stage, threshold, .. } => {
                // The canvas rows this band covers were merged before the
                // unit was released (they are its declared inputs); the
                // band is materialized node-locally, modeled as a local
                // read of its 1 byte/pixel rows.
                let band = stage.canvas_rows(r0, r1)?;
                let io = self.cost.split_input((rows * width) as u64, 0);
                Ok((crate::vector::threshold_mask(&band, *threshold), io))
            }
        }
    }
}

impl DagStage for LabelStage<'_> {
    fn name(&self) -> &'static str {
        "vectorize"
    }

    fn gates(&self) -> Vec<Gate> {
        match &self.source {
            MaskSource::Given(_) => Vec::new(),
            // Bands are plannable as soon as the canvas geometry exists.
            MaskSource::Mosaic { stage_index, .. } => vec![Gate::Planned(*stage_index)],
        }
    }

    fn plan(&self) -> Result<StagePlan> {
        let (width, height, tile_deps, plan_io_secs) = match &self.source {
            MaskSource::Given(mask) => {
                if mask.width == 0 || mask.height == 0 {
                    return Err(DifetError::Job("vector job: empty mask".into()));
                }
                if mask.data.len() != mask.width * mask.height {
                    return Err(DifetError::Job(format!(
                        "vector job: mask raster has {} cells, {}×{} needs {}",
                        mask.data.len(),
                        mask.width,
                        mask.height,
                        mask.width * mask.height
                    )));
                }
                // Shuffle: the mask raster goes into DFS header-free
                // (1 byte/pixel) so every band is one contiguous range.
                self.dfs.write_file(&self.spec.mask_path, &mask.data, NodeId(0))?;
                let io = self
                    .cost
                    .hdfs_write(mask.data.len() as u64, self.cfg.cluster.replication);
                (mask.width, mask.height, None, io)
            }
            MaskSource::Mosaic { stage, stage_index, .. } => {
                let (width, height, rects) = stage.planned_tiles();
                if width == 0 || height == 0 {
                    return Err(DifetError::Job("vector job: empty canvas".into()));
                }
                (width, height, Some((*stage_index, rects)), 0.0)
            }
        };

        let mut tasks = Vec::new();
        let mut units = Vec::new();
        for (tile_id, rect) in crate::vector::band_rects(width, height, self.spec.band_rows)
            .into_iter()
            .enumerate()
        {
            let byte_start = (rect[0] * width) as u64;
            let byte_end = (rect[1] * width) as u64;
            let (preferred, deps) = match &tile_deps {
                // Standalone: locality toward the mask band's blocks.
                None => (
                    self.dfs
                        .locate_range(&self.spec.mask_path, byte_start, byte_end)
                        .unwrap_or_default(),
                    Vec::new(),
                ),
                // Mosaic mode: inputs are the canvas tiles covering the
                // band's rows (full-width bands cross every tile column).
                Some((stage_index, rects)) => (
                    Vec::new(),
                    rects
                        .iter()
                        .enumerate()
                        .filter(|(_, r)| r[0] < rect[1] && rect[0] < r[1])
                        .map(|(unit, _)| UnitRef { stage: *stage_index, unit })
                        .collect(),
                ),
            };
            tasks.push(LabelTile {
                tile_id,
                rect,
                byte_start,
                byte_end,
                mask_path: self.spec.mask_path.clone(),
                labels_path: format!("{}/{tile_id}", self.spec.labels_dir),
                preferred_nodes: preferred.clone(),
            });
            units.push(UnitSpec { deps, preferred_nodes: preferred });
        }
        *self.done.lock().unwrap() = vec![false; tasks.len()];
        *self.planned.lock().unwrap() = Some(Arc::new(VectorPlanInfo { width, height, tasks }));
        Ok(StagePlan { units, plan_io_secs })
    }

    /// The band body: materialize this band's mask rows, run tile-local
    /// CCL with row-level progress and cooperative cancellation, and
    /// shuffle the encoded tile labels back into a CRC-guarded DFS file
    /// for the merge stage.
    fn run_unit(
        &self,
        unit: usize,
        handle: &TaskHandle,
        node: NodeId,
    ) -> Result<Option<UnitOutput>> {
        injected_failure(self.hooks, "tile", unit, handle)?;
        let info = self.plan_info();
        let task = &info.tasks[unit];
        let [r0, r1, c0, c1] = task.rect;
        let (rows, width) = (r1 - r0, c1 - c0);

        let (band, mut io_secs) = self.band_mask(task, node)?;
        debug_assert_eq!((band.width, band.height), (width, rows));

        // --- label the band locally ----------------------------------------
        let t0 = std::time::Instant::now();
        let Some(local) =
            crate::vector::label_rect_while(&band, [0, rows, 0, width], &mut |done, total| {
                handle.report_progress(done as f64 / total.max(1) as f64);
                !handle.cancelled()
            })?
        else {
            return Ok(None); // cancelled: the twin won
        };
        let tile = local.offset_rows(r0);
        let compute_ns = t0.elapsed().as_nanos() as u64;
        if handle.cancelled() {
            return Ok(None);
        }
        self.tile_hist.observe(compute_ns as f64 * 1e-9);

        // --- output: shuffle the tile labels into DFS ----------------------
        // (bit-identical across attempts, so a retry or losing twin
        // rewriting the same path is harmless.)
        let encoded = shuffle::encode_labels(task.tile_id as u64, &tile);
        self.dfs.write_file(&task.labels_path, &encoded, node)?;
        io_secs += self
            .cost
            .hdfs_write(encoded.len() as u64, self.cfg.cluster.replication);

        Ok(Some(UnitOutput {
            payload: Box::new(()),
            compute_ns,
            io_secs,
        }))
    }

    fn merge(&self, unit: usize, _payload: Box<dyn Any + Send>) -> Result<()> {
        self.tiles_counter.inc();
        self.done.lock().unwrap()[unit] = true;
        Ok(())
    }

    /// Reduce: fetch the shuffled tile labels, merge the seams with the
    /// union-find, publish the diagnostics gauges.  In defer mode the
    /// merge is a downstream stage's tree of pairwise band merges — the
    /// historical serial loop below is the scaling collapse it replaces.
    fn finalize(&self) -> Result<()> {
        let info = self.plan_info();
        if !self.done.lock().unwrap().iter().all(|&d| d) {
            return Err(DifetError::Job("vector tile lost its result".into()));
        }
        if self.defer {
            return Ok(());
        }
        let mut tiles = Vec::with_capacity(info.tasks.len());
        for task in &info.tasks {
            let (bytes, _) = self.dfs.read_file(&task.labels_path, NodeId(0))?;
            let (id, tile) = shuffle::decode_labels(&bytes)?;
            if id != task.tile_id as u64 {
                return Err(DifetError::Job(format!(
                    "label file routing mixup: wanted {}, got {id}",
                    task.tile_id
                )));
            }
            tiles.push(tile);
        }
        let (labels, objects, mstats) =
            crate::vector::merge_tile_labels(info.width, info.height, &tiles)?;
        self.residual_gauge.set(mstats.max_merge_residual() as f64);
        self.objects_counter.add(objects.len() as u64);
        *self.merged.lock().unwrap() = Some((labels, objects, mstats));
        Ok(())
    }
}
