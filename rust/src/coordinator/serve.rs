//! Multi-tenant job service: MANY concurrent DAG jobs on ONE shared
//! slot pool.
//!
//! Every other `difet` entry point builds a cluster, runs one DAG and
//! exits — so the fixed job startup (PR 8's critical-path attribution
//! shows it as a dominant serial term) is paid per invocation.  The
//! ROADMAP's north star ("serve heavy traffic from millions of users")
//! needs the opposite shape: a persistent coordinator that pays startup
//! once and then streams heterogeneous jobs through the same worker
//! slots.  [`JobService`] is that coordinator:
//!
//! * **One pool, many DAGs.**  A single fair-share [`Scheduler`]
//!   (`Scheduler::new_fair`) executes the units of every admitted job;
//!   each job keeps its own stage/unit state machine (a per-job copy of
//!   the `dag.rs` pipelined executor) so plans, merges and finalizes
//!   stay attributable to the job that owns them.
//! * **Admission control.**  At most `serve.max_concurrent_jobs` jobs
//!   run at once; due arrivals beyond that wait in a
//!   [`BoundedQueue`](super::backpressure::BoundedQueue) of depth
//!   `serve.queue_depth`, and arrivals past the bound are *rejected* —
//!   the queue can never grow without limit.
//! * **Fair share + preemption.**  Slots free up into a
//!   deficit-round-robin pick over tenants (quota
//!   `serve.quotas`/`serve.tenants`); a higher-priority arrival may
//!   cooperatively preempt a running lower-priority unit
//!   (`serve.preemption`), reusing the kill machinery speculative twins
//!   already exercise.
//! * **Per-job determinism audit.**  Every admitted job threads its own
//!   [`HbChecker`] through the shared pool, so the bit-identical-per-job
//!   invariant is *checked*, not assumed, under co-scheduling.
//!
//! # Virtual time
//!
//! The pool inherits the DAG runtime's event-driven virtual clock: unit
//! completion is `max(slot_clock, ready) + overhead + io + compute`.
//! Pool startup (`CostModel::job_startup`) initializes every slot clock
//! and the admission frontier ONCE — jobs admitted later never pay it
//! again.  A job's admission time is `max(arrival, frontier)` where the
//! frontier advances to each processed completion; with one slot the
//! frontier is exactly the event order, so the whole simulation is
//! deterministic; with many slots the *outputs* stay bit-identical and
//! the admission/fairness invariants hold while timings are
//! approximately ordered (same contract `dag.rs` documents for its
//! multi-slot timings).
//!
//! Queue-wait is measured from *arrival* to *admission* (early arrivals
//! wait out pool startup too — that is part of the service experience).
//! Cooperative preemption kills are modeled as instantaneous: a killed
//! attempt advances no virtual clock, and the refunded retry re-runs
//! when the unit is next granted.
//!
//! # Example
//!
//! ```
//! use difet::config::Config;
//! use difet::coordinator::serve::{synthetic_jobs, JobService};
//! use difet::metrics::Registry;
//!
//! let mut cfg = Config::new();
//! cfg.cluster.nodes = 2;
//! cfg.cluster.slots_per_node = 2;
//! cfg.serve.jobs = 4;
//! let mut svc = JobService::new(&cfg);
//! for job in synthetic_jobs(&cfg) {
//!     svc.submit(job);
//! }
//! let report = svc.run(&Registry::new()).unwrap();
//! assert_eq!(report.completed() + report.rejected(), 4);
//! assert!(report.fairness_ok());
//! ```

use std::any::Any;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::analysis::dag_check;
use crate::analysis::hb::HbChecker;
use crate::cluster::CostModel;
use crate::config::Config;
use crate::dfs::NodeId;
use crate::metrics::Registry;
use crate::util::rng::Pcg32;
use crate::util::{DifetError, Result, Stopwatch};

use super::backpressure::BoundedQueue;
use super::dag::{DagStage, Gate, StagePlan, UnitOutput, UnitRef, UnitSpec};
use super::scheduler::{monotonic_clock, Assignment, Scheduler, TaskHandle, WorkItem};

/// Shared observable-output sink a job's synthetic stages merge into —
/// the job's "result file".  [`sink_digest`] folds it into the u64 the
/// bit-parity tests compare between solo and shared runs.
pub type JobSink = Arc<Mutex<BTreeMap<(usize, usize), u64>>>;

/// One job submitted to the service: a whole DAG plus its tenant,
/// priority class (higher runs first, may preempt) and virtual arrival
/// time.
pub struct JobSpec {
    pub name: String,
    pub tenant: usize,
    /// Priority class; within the pool the highest backlogged class is
    /// served first and (when enabled) may preempt lower classes.
    pub priority: u8,
    /// Virtual-clock arrival (seconds since service start).
    pub arrival_secs: f64,
    pub stages: Vec<Box<dyn DagStage + Send + Sync>>,
    /// Observable output map, if the job's stages write one (the
    /// synthetic workload does; real stages may sink elsewhere).
    pub sink: Option<JobSink>,
}

// ---------------------------------------------------------------------------
// Reports.
// ---------------------------------------------------------------------------

/// Per-job outcome: admission/finish times on the virtual clock plus
/// the output digest for bit-parity checks.
#[derive(Debug, Clone)]
pub struct JobReport {
    pub name: String,
    pub tenant: usize,
    pub priority: u8,
    pub arrival_secs: f64,
    pub admit_secs: f64,
    pub finish_secs: f64,
    pub rejected: bool,
    /// Units executed across all stages (0 when rejected).
    pub units: usize,
    /// Folded output digest (when the job carried a sink).
    pub digest: Option<u64>,
}

impl JobReport {
    /// Arrival → admission (includes pool startup for early arrivals).
    pub fn queue_wait_secs(&self) -> f64 {
        (self.admit_secs - self.arrival_secs).max(0.0)
    }

    /// End-to-end: arrival → last merge of the job.
    pub fn latency_secs(&self) -> f64 {
        (self.finish_secs - self.arrival_secs).max(0.0)
    }
}

/// Per-tenant aggregate: quota, job counts, granted units and exact
/// latency/queue-wait percentiles over the tenant's completed jobs.
#[derive(Debug, Clone)]
pub struct TenantReport {
    pub tenant: usize,
    pub quota: usize,
    pub submitted: usize,
    pub completed: usize,
    pub rejected: usize,
    /// Unit attempts the fair-share scheduler granted this tenant.
    pub granted_units: u64,
    pub latency_p50: f64,
    pub latency_p95: f64,
    pub latency_p99: f64,
    pub queue_wait_p50: f64,
    pub queue_wait_p95: f64,
    pub queue_wait_p99: f64,
}

/// The service-level report `difet serve` renders.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub sim_seconds: f64,
    pub wall_seconds: f64,
    pub nodes: usize,
    pub slots_per_node: usize,
    pub startup_secs: f64,
    pub max_concurrent_jobs: usize,
    pub queue_depth_bound: usize,
    /// Peak concurrently running jobs (≤ `max_concurrent_jobs`).
    pub max_running_jobs: u64,
    /// Peak admission-queue depth (≤ `queue_depth_bound`).
    pub max_queue_depth: u64,
    pub preemptions: u64,
    /// Fair-share audit: grants to an at-quota tenant while an
    /// under-quota tenant had backlogged work.  0 = fairness held.
    pub fairness_violations: u64,
    pub hb_checks: u64,
    pub jobs: Vec<JobReport>,
    pub tenants: Vec<TenantReport>,
}

impl ServeReport {
    pub fn completed(&self) -> usize {
        self.jobs.iter().filter(|j| !j.rejected).count()
    }

    pub fn rejected(&self) -> usize {
        self.jobs.iter().filter(|j| j.rejected).count()
    }

    /// The fair-share property the e2e suite asserts: no tenant was
    /// served past its quota while another sat under quota with work.
    pub fn fairness_ok(&self) -> bool {
        self.fairness_violations == 0
    }

    pub fn job(&self, name: &str) -> Option<&JobReport> {
        self.jobs.iter().find(|j| j.name == name)
    }

    /// Human-readable latency-percentile and fairness report.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "difet serve — {} nodes x {} slots, pool startup {:.1}s paid once\n",
            self.nodes, self.slots_per_node, self.startup_secs
        ));
        out.push_str(&format!(
            "jobs: {} submitted, {} completed, {} rejected; sim {:.2}s (wall {:.3}s)\n",
            self.jobs.len(),
            self.completed(),
            self.rejected(),
            self.sim_seconds,
            self.wall_seconds
        ));
        out.push_str(&format!(
            "admission: peak {} running (bound {}), peak queue {} (bound {})\n",
            self.max_running_jobs,
            self.max_concurrent_jobs,
            self.max_queue_depth,
            self.queue_depth_bound
        ));
        out.push_str(&format!(
            "scheduling: {} preemptions, fairness {} ({} violations), {} hb checks\n",
            self.preemptions,
            if self.fairness_ok() { "OK" } else { "VIOLATED" },
            self.fairness_violations,
            self.hb_checks
        ));
        out.push_str(
            "tenant  quota  jobs  done  rej  granted  lat p50      p95      p99   wait p99\n",
        );
        for t in &self.tenants {
            out.push_str(&format!(
                "{:>6}  {:>5}  {:>4}  {:>4}  {:>3}  {:>7}  {:>7.2}s {:>7.2}s {:>7.2}s  {:>7.2}s\n",
                t.tenant,
                t.quota,
                t.submitted,
                t.completed,
                t.rejected,
                t.granted_units,
                t.latency_p50,
                t.latency_p95,
                t.latency_p99,
                t.queue_wait_p99
            ));
        }
        out
    }
}

/// Exact percentile over an ascending-sorted sample (nearest-rank);
/// 0.0 for an empty sample.
fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 * q).ceil() as usize).clamp(1, sorted.len()) - 1;
    sorted[idx]
}

fn secs_to_ns(secs: f64) -> u64 {
    (secs.max(0.0) * 1e9) as u64
}

/// splitmix-style mixer: the synthetic stage values and job digests.
fn mix(a: u64, b: u64) -> u64 {
    let mut z = a ^ b.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z ^ (z >> 31)
}

/// Fold a job's sink into one u64 — what the solo-vs-shared bit-parity
/// property compares.
pub fn sink_digest(sink: &JobSink) -> u64 {
    let m = sink.lock().unwrap();
    let mut d = 0x00D1_FE70_u64;
    for (&(s, u), &v) in m.iter() {
        d = mix(d, mix(s as u64, mix(u as u64, v)));
    }
    d
}

// ---------------------------------------------------------------------------
// Executor internals.
// ---------------------------------------------------------------------------

/// Scheduler work item: one (job, stage, unit) triple, tagged with the
/// owning tenant and priority class for the fair-share pick.
#[derive(Clone)]
struct ServeTask {
    job: usize,
    unit: UnitRef,
    preferred: Vec<NodeId>,
    tenant: usize,
    priority: u8,
}

impl WorkItem for ServeTask {
    fn preferred_nodes(&self) -> &[NodeId] {
        &self.preferred
    }

    fn tenant(&self) -> usize {
        self.tenant
    }

    fn priority(&self) -> u8 {
        self.priority
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum StageStatus {
    Blocked,
    Planning,
    Running,
    Finalizing,
    Done,
}

struct UnitRun {
    deps_remaining: usize,
    dependents: Vec<UnitRef>,
    preferred: Vec<NodeId>,
    released: bool,
    merged: bool,
    ready_ns: u64,
    completion_ns: u64,
}

struct StageRun {
    status: StageStatus,
    units: Vec<UnitRun>,
    outstanding: usize,
    plan_io_ns: u64,
    open_ns: u64,
    close_ns: u64,
}

impl StageRun {
    fn new() -> Self {
        StageRun {
            status: StageStatus::Blocked,
            units: Vec::new(),
            outstanding: 0,
            plan_io_ns: 0,
            open_ns: 0,
            close_ns: 0,
        }
    }

    fn planned(&self) -> bool {
        matches!(
            self.status,
            StageStatus::Running | StageStatus::Finalizing | StageStatus::Done
        )
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum JobStatus {
    /// Not arrived / not yet processed by the admission pump.
    Pending,
    /// Waiting in the bounded admission queue.
    Queued,
    Running,
    Done,
    Rejected,
}

struct JobRun {
    status: JobStatus,
    stages: Vec<StageRun>,
    done_stages: usize,
    units_total: usize,
    admit_ns: u64,
    finish_ns: u64,
}

impl JobRun {
    fn new(stages: usize) -> Self {
        JobRun {
            status: JobStatus::Pending,
            stages: (0..stages).map(|_| StageRun::new()).collect(),
            done_stages: 0,
            units_total: 0,
            admit_ns: 0,
            finish_ns: 0,
        }
    }
}

struct ServeState {
    jobs: Vec<JobRun>,
    /// Index into `order` of the next unprocessed arrival.
    next_arrival: usize,
    running_jobs: usize,
    /// Done + Rejected.
    finished_jobs: usize,
    max_running: u64,
    max_queue_depth: u64,
    /// Virtual admission frontier: max(pool startup, processed job
    /// completions).  Queued jobs admit at `max(arrival, frontier)`.
    frontier_ns: u64,
}

enum Act {
    Plan(usize),
    Finalize(usize),
}

struct ServeExec<'a> {
    jobs: &'a [JobSpec],
    /// Job indices sorted by (arrival, submission order).
    order: Vec<usize>,
    arrival_ns: Vec<u64>,
    sched: Scheduler<ServeTask>,
    state: Mutex<ServeState>,
    /// The admission queue — the seed's backpressure primitive, finally
    /// load-bearing: `try_push` rejection IS the admission bound.
    waiting: BoundedQueue<usize>,
    /// One happens-before checker per job (audit mode): the per-job
    /// bit-identity invariant checked under co-scheduling.  Lock order
    /// as in `dag.rs`: checkers never take `state`.
    hb: Option<Vec<HbChecker>>,
    startup_ns: u64,
    overhead_ns: u64,
    max_slot_ns: AtomicU64,
    nodes: usize,
    slots_per_node: usize,
    max_concurrent: usize,
}

impl<'a> ServeExec<'a> {
    // -- admission ----------------------------------------------------------

    /// Process arrivals and queue drains at virtual time `now_ns`.
    /// Returns the jobs admitted (their DAGs still need an initial
    /// `job_advance`).  Invariants: the queue drains before new
    /// arrivals are considered (FIFO admission), and an arrival is
    /// queued/rejected only once it is *due* (arrival ≤ frontier) with
    /// the pool full — future arrivals admit directly when a slot is
    /// free, which is what advances virtual time across idle gaps.
    fn pump(&self, now_ns: u64) -> Vec<usize> {
        let mut admitted = Vec::new();
        let mut st = self.state.lock().unwrap();
        st.frontier_ns = st.frontier_ns.max(now_ns);
        loop {
            if st.running_jobs < self.max_concurrent {
                if let Some(j) = self.waiting.try_pop() {
                    let at = st.frontier_ns.max(self.arrival_ns[j]);
                    self.admit(&mut st, j, at, &mut admitted);
                    continue;
                }
            }
            let Some(&j) = self.order.get(st.next_arrival) else {
                break;
            };
            let arr = self.arrival_ns[j];
            if st.running_jobs < self.max_concurrent {
                st.next_arrival += 1;
                let at = st.frontier_ns.max(arr);
                self.admit(&mut st, j, at, &mut admitted);
            } else if arr <= st.frontier_ns {
                st.next_arrival += 1;
                if self.waiting.try_push(j).is_ok() {
                    st.jobs[j].status = JobStatus::Queued;
                    st.max_queue_depth = st.max_queue_depth.max(self.waiting.len() as u64);
                } else {
                    // Queue at bound: reject outright (backpressure).
                    let jr = &mut st.jobs[j];
                    jr.status = JobStatus::Rejected;
                    jr.admit_ns = arr;
                    jr.finish_ns = arr;
                    st.finished_jobs += 1;
                }
            } else {
                break;
            }
        }
        admitted
    }

    fn admit(&self, st: &mut ServeState, j: usize, at_ns: u64, admitted: &mut Vec<usize>) {
        let jr = &mut st.jobs[j];
        jr.admit_ns = at_ns;
        if self.jobs[j].stages.is_empty() {
            // Degenerate zero-stage job: done the instant it is admitted.
            jr.status = JobStatus::Done;
            jr.finish_ns = at_ns;
            st.finished_jobs += 1;
            return;
        }
        jr.status = JobStatus::Running;
        st.running_jobs += 1;
        st.max_running = st.max_running.max(st.running_jobs as u64);
        admitted.push(j);
    }

    /// Post-event driver: pump admissions for a completed job's virtual
    /// finish time, run every newly admitted job's state machine (which
    /// may itself finish zero-unit jobs and admit more), then close the
    /// pool once every job is accounted for.
    fn after_job_event(&self, fin: Option<u64>) -> Result<()> {
        let mut pending = match fin {
            Some(f) => self.pump(f),
            None => Vec::new(),
        };
        let mut i = 0;
        while i < pending.len() {
            let j = pending[i];
            i += 1;
            if let Some(f2) = self.job_advance(j)? {
                let more = self.pump(f2);
                pending.extend(more);
            }
        }
        self.maybe_close();
        Ok(())
    }

    fn maybe_close(&self) {
        let done = {
            let st = self.state.lock().unwrap();
            st.finished_jobs == self.jobs.len()
        };
        if done {
            self.sched.close();
        }
    }

    // -- per-job DAG state machine (dag.rs, scoped to one job) --------------

    fn gates_met(&self, jr: &JobRun, gates: &[Gate]) -> bool {
        gates.iter().all(|g| match *g {
            Gate::Planned(p) => p < jr.stages.len() && jr.stages[p].planned(),
            Gate::Completed(p) => p < jr.stages.len() && jr.stages[p].status == StageStatus::Done,
        })
    }

    fn next_act(&self, job: usize, jr: &mut JobRun) -> Option<Act> {
        if let Some(i) = jr
            .stages
            .iter()
            .position(|s| s.status == StageStatus::Running && s.outstanding == 0)
        {
            jr.stages[i].status = StageStatus::Finalizing;
            return Some(Act::Finalize(i));
        }
        let blocked: Vec<usize> = jr
            .stages
            .iter()
            .enumerate()
            .filter(|(_, s)| s.status == StageStatus::Blocked)
            .map(|(i, _)| i)
            .collect();
        for i in blocked {
            if self.gates_met(jr, &self.jobs[job].stages[i].gates()) {
                jr.stages[i].status = StageStatus::Planning;
                return Some(Act::Plan(i));
            }
        }
        None
    }

    /// Drive one job's planning/finalization; `Some(finish_ns)` when
    /// this call completed the job.  User code (plan/finalize) runs
    /// outside the state lock, as in `dag.rs`.
    fn job_advance(&self, job: usize) -> Result<Option<u64>> {
        let mut finished = None;
        loop {
            let act = {
                let mut st = self.state.lock().unwrap();
                if st.jobs[job].status != JobStatus::Running {
                    return Ok(finished);
                }
                let jr = &mut st.jobs[job];
                // Split the borrow: next_act needs &self for specs.
                match self.next_act(job, jr) {
                    Some(act) => act,
                    None => {
                        let jr = &st.jobs[job];
                        let idle = jr
                            .stages
                            .iter()
                            .all(|s| matches!(s.status, StageStatus::Blocked | StageStatus::Done));
                        if idle && jr.done_stages < jr.stages.len() {
                            return Err(DifetError::Job(format!(
                                "job '{}' stalled: stage gates never satisfiable",
                                self.jobs[job].name
                            )));
                        }
                        return Ok(finished);
                    }
                }
            };
            match act {
                Act::Plan(i) => {
                    let plan = self.jobs[job].stages[i].plan()?;
                    let mut st = self.state.lock().unwrap();
                    self.install_plan(&mut st, job, i, plan)?;
                }
                Act::Finalize(i) => {
                    self.jobs[job].stages[i].finalize()?;
                    let mut st = self.state.lock().unwrap();
                    let jr = &mut st.jobs[job];
                    jr.stages[i].status = StageStatus::Done;
                    jr.done_stages += 1;
                    if jr.done_stages == jr.stages.len() {
                        let fin = jr
                            .stages
                            .iter()
                            .map(|s| s.close_ns)
                            .max()
                            .unwrap_or(jr.admit_ns)
                            .max(jr.admit_ns);
                        jr.status = JobStatus::Done;
                        jr.finish_ns = fin;
                        st.running_jobs -= 1;
                        st.finished_jobs += 1;
                        finished = Some(fin);
                    }
                }
            }
        }
    }

    fn install_plan(
        &self,
        st: &mut ServeState,
        job: usize,
        stage: usize,
        plan: StagePlan,
    ) -> Result<()> {
        let spec_stage = &self.jobs[job].stages[stage];
        // Layer-2 audit, per job: same plan validator the DAG runtime
        // uses, so a malformed plan is rejected before any unit state.
        let unit_defs: Vec<dag_check::UnitDef> = plan
            .units
            .iter()
            .map(|spec| dag_check::UnitDef {
                deps: spec.deps.iter().map(|d| (d.stage, d.unit)).collect(),
                preferred: spec.preferred_nodes.iter().map(|n| n.0).collect(),
            })
            .collect();
        let planned_units: Vec<Option<usize>> = st.jobs[job]
            .stages
            .iter()
            .enumerate()
            .map(|(s, up)| (s != stage && up.planned()).then(|| up.units.len()))
            .collect();
        let issues = dag_check::validate_plan(
            spec_stage.name(),
            stage,
            &unit_defs,
            &planned_units,
            self.nodes,
        );
        if !issues.is_empty() {
            return Err(DifetError::Job(format!(
                "job '{}': {}",
                self.jobs[job].name,
                issues.join("; ")
            )));
        }
        if let Some(hbs) = &self.hb {
            for (u, spec) in plan.units.iter().enumerate() {
                let deps: Vec<(usize, usize)> =
                    spec.deps.iter().map(|d| (d.stage, d.unit)).collect();
                hbs[job].register_unit((stage, u), &deps);
            }
        }
        // Resolve deps — immutable reads over this job's earlier stages;
        // intra-stage deps (tree merges) count but never mark merged.
        let jr = &mut st.jobs[job];
        let mut units = Vec::with_capacity(plan.units.len());
        for spec in &plan.units {
            let mut deps_remaining = 0usize;
            let mut ready_ns = 0u64;
            for d in &spec.deps {
                if d.stage == stage {
                    deps_remaining += 1;
                    continue;
                }
                let dep_unit = &jr.stages[d.stage].units[d.unit];
                if dep_unit.merged {
                    ready_ns = ready_ns.max(dep_unit.completion_ns);
                } else {
                    deps_remaining += 1;
                }
            }
            units.push(UnitRun {
                deps_remaining,
                dependents: Vec::new(),
                preferred: spec.preferred_nodes.clone(),
                released: false,
                merged: false,
                ready_ns,
                completion_ns: 0,
            });
        }
        for (u, spec) in plan.units.iter().enumerate() {
            for d in &spec.deps {
                if d.stage == stage {
                    units[d.unit].dependents.push(UnitRef { stage, unit: u });
                } else if !jr.stages[d.stage].units[d.unit].merged {
                    jr.stages[d.stage].units[d.unit]
                        .dependents
                        .push(UnitRef { stage, unit: u });
                }
            }
        }
        // Stage opens at the latest of admission and its gate times —
        // NO per-job startup here: the pool paid it once at boot.
        let mut base = jr.admit_ns;
        for g in spec_stage.gates() {
            base = base.max(match g {
                Gate::Planned(p) => jr.stages[p].open_ns,
                Gate::Completed(p) => jr.stages[p].close_ns,
            });
        }
        let plan_io_ns = secs_to_ns(plan.plan_io_secs);
        let open = base + plan_io_ns;
        jr.units_total += units.len();
        {
            let s = &mut jr.stages[stage];
            s.plan_io_ns = plan_io_ns;
            s.outstanding = units.len();
            s.units = units;
            s.status = StageStatus::Running;
            s.open_ns = open;
            s.close_ns = open;
        }
        let ready: Vec<usize> = jr.stages[stage]
            .units
            .iter()
            .enumerate()
            .filter(|(_, u)| u.deps_remaining == 0)
            .map(|(u, _)| u)
            .collect();
        for unit in ready {
            self.release_unit(st, job, UnitRef { stage, unit });
        }
        Ok(())
    }

    fn release_unit(&self, st: &mut ServeState, job: usize, r: UnitRef) {
        let preferred = {
            let s = &mut st.jobs[job].stages[r.stage];
            let u = &mut s.units[r.unit];
            debug_assert!(!u.released && u.deps_remaining == 0);
            u.released = true;
            u.ready_ns = u.ready_ns.max(s.open_ns);
            u.preferred.clone()
        };
        // Release recorded before the scheduler can hand the unit out.
        if let Some(hbs) = &self.hb {
            hbs[job].on_release((r.stage, r.unit));
        }
        self.sched.push(ServeTask {
            job,
            unit: r,
            preferred,
            tenant: self.jobs[job].tenant,
            priority: self.jobs[job].priority,
        });
    }

    fn complete_unit(&self, job: usize, r: UnitRef, completion_ns: u64) {
        let mut st = self.state.lock().unwrap();
        let dependents = {
            let s = &mut st.jobs[job].stages[r.stage];
            let u = &mut s.units[r.unit];
            debug_assert!(!u.merged);
            u.merged = true;
            u.completion_ns = completion_ns;
            let deps = std::mem::take(&mut u.dependents);
            s.outstanding -= 1;
            s.close_ns = s.close_ns.max(completion_ns);
            deps
        };
        for d in dependents {
            let release = {
                let du = &mut st.jobs[job].stages[d.stage].units[d.unit];
                du.ready_ns = du.ready_ns.max(completion_ns);
                du.deps_remaining -= 1;
                du.deps_remaining == 0
            };
            if release {
                self.release_unit(&mut st, job, d);
            }
        }
    }

    // -- the shared worker slot --------------------------------------------

    /// Worker-slot body over the WHOLE service: the slot's virtual clock
    /// starts at pool startup (paid once) and then serves units of any
    /// admitted job the fair-share scheduler grants it.
    fn slot_loop(&self, node: NodeId) {
        let mut clock_ns = self.startup_ns;
        loop {
            let (task, handle) = match self.sched.next_assignment(node) {
                Assignment::Done => break,
                Assignment::Run(task, handle) => (task, handle),
            };
            let UnitRef { stage, unit } = task.unit;
            if let Some(hbs) = &self.hb {
                hbs[task.job].on_attempt_start((stage, unit), handle.launch_seq, handle.speculative);
            }
            let ready_ns = {
                let st = self.state.lock().unwrap();
                st.jobs[task.job].stages[stage].units[unit].ready_ns
            };
            let unit_result = self.jobs[task.job].stages[stage].run_unit(unit, &handle, node);
            match unit_result {
                Ok(Some(out)) => {
                    let io_ns = secs_to_ns(out.io_secs);
                    let virtual_ns = self.overhead_ns + io_ns + out.compute_ns;
                    let begin = clock_ns.max(ready_ns);
                    let completion = begin + virtual_ns;
                    clock_ns = completion;
                    let won = self.sched.report_success(&handle);
                    if won {
                        match self.jobs[task.job].stages[stage].merge(unit, out.payload) {
                            Ok(()) => {
                                if let Some(hbs) = &self.hb {
                                    hbs[task.job].on_merge((stage, unit));
                                }
                                self.complete_unit(task.job, task.unit, completion);
                                let res = self
                                    .job_advance(task.job)
                                    .and_then(|fin| self.after_job_event(fin));
                                if let Err(e) = res {
                                    self.sched.abort(e.to_string());
                                }
                            }
                            Err(e) => self.sched.abort(e.to_string()),
                        }
                    }
                }
                // Cooperative kill (speculative loser or preemption
                // victim): no virtual time, the scheduler decides
                // whether to requeue (preempted) or drop (lost twin).
                Ok(None) => self.sched.report_cancelled(&handle),
                Err(e) => {
                    self.sched.report_failure(&handle, &e.to_string());
                }
            }
        }
        self.max_slot_ns.fetch_max(clock_ns, Ordering::Relaxed);
    }

    // -- reporting ----------------------------------------------------------

    fn report(
        &self,
        wall_seconds: f64,
        quotas: &[usize],
        hb_checks: u64,
        registry: &Registry,
    ) -> Result<ServeReport> {
        let st = self.state.lock().unwrap();
        let mut sim_ns = self.max_slot_ns.load(Ordering::Relaxed).max(st.frontier_ns);
        let mut jobs = Vec::with_capacity(self.jobs.len());
        for (j, spec) in self.jobs.iter().enumerate() {
            let jr = &st.jobs[j];
            if jr.status != JobStatus::Done && jr.status != JobStatus::Rejected {
                return Err(DifetError::Job(format!(
                    "job '{}' ended in non-terminal state {:?}",
                    spec.name, jr.status
                )));
            }
            if jr.status == JobStatus::Done {
                sim_ns = sim_ns.max(jr.finish_ns);
            }
            jobs.push(JobReport {
                name: spec.name.clone(),
                tenant: spec.tenant,
                priority: spec.priority,
                arrival_secs: self.arrival_ns[j] as f64 * 1e-9,
                admit_secs: jr.admit_ns as f64 * 1e-9,
                finish_secs: jr.finish_ns as f64 * 1e-9,
                rejected: jr.status == JobStatus::Rejected,
                units: jr.units_total,
                digest: spec.sink.as_ref().map(sink_digest),
            });
        }
        let max_running_jobs = st.max_running;
        let max_queue_depth = st.max_queue_depth;
        drop(st);

        let granted = self.sched.tenant_granted();
        let mut tenants = Vec::with_capacity(quotas.len());
        for (t, &quota) in quotas.iter().enumerate() {
            let mine: Vec<&JobReport> = jobs.iter().filter(|r| r.tenant == t).collect();
            let done: Vec<&&JobReport> = mine.iter().filter(|r| !r.rejected).collect();
            let mut lat: Vec<f64> = done.iter().map(|r| r.latency_secs()).collect();
            let mut wait: Vec<f64> = done.iter().map(|r| r.queue_wait_secs()).collect();
            let lat_h = registry.histogram(&format!("tenant_job_latency_seconds_{t}"));
            let wait_h = registry.histogram(&format!("tenant_queue_wait_seconds_{t}"));
            for &v in &lat {
                lat_h.observe(v);
            }
            for &v in &wait {
                wait_h.observe(v);
            }
            registry
                .counter(&format!("tenant_jobs_submitted_{t}"))
                .add(mine.len() as u64);
            registry
                .counter(&format!("tenant_jobs_completed_{t}"))
                .add(done.len() as u64);
            registry
                .counter(&format!("tenant_jobs_rejected_{t}"))
                .add((mine.len() - done.len()) as u64);
            lat.sort_by(f64::total_cmp);
            wait.sort_by(f64::total_cmp);
            tenants.push(TenantReport {
                tenant: t,
                quota,
                submitted: mine.len(),
                completed: done.len(),
                rejected: mine.len() - done.len(),
                granted_units: granted.get(t).copied().unwrap_or(0),
                latency_p50: percentile(&lat, 0.50),
                latency_p95: percentile(&lat, 0.95),
                latency_p99: percentile(&lat, 0.99),
                queue_wait_p50: percentile(&wait, 0.50),
                queue_wait_p95: percentile(&wait, 0.95),
                queue_wait_p99: percentile(&wait, 0.99),
            });
        }

        let preemptions = self.sched.preemptions.load(Ordering::Relaxed);
        let fairness_violations = self.sched.fairness_violations.load(Ordering::Relaxed);
        registry.counter("serve_preemptions").add(preemptions);
        registry
            .counter("serve_fairness_violations")
            .add(fairness_violations);
        registry
            .gauge("serve_running_jobs_max")
            .set(max_running_jobs as f64);
        registry
            .gauge("serve_queue_depth_max")
            .set(max_queue_depth as f64);

        Ok(ServeReport {
            sim_seconds: sim_ns as f64 * 1e-9,
            wall_seconds,
            nodes: self.nodes,
            slots_per_node: self.slots_per_node,
            startup_secs: self.startup_ns as f64 * 1e-9,
            max_concurrent_jobs: self.max_concurrent,
            queue_depth_bound: self.waiting.capacity(),
            max_running_jobs,
            max_queue_depth,
            preemptions,
            fairness_violations,
            hb_checks,
            jobs,
            tenants,
        })
    }
}

// ---------------------------------------------------------------------------
// The service.
// ---------------------------------------------------------------------------

/// The persistent multi-tenant coordinator: submit jobs, then `run`
/// drains them all through one shared fair-share slot pool.
pub struct JobService {
    cfg: Config,
    jobs: Vec<JobSpec>,
}

impl JobService {
    pub fn new(cfg: &Config) -> Self {
        JobService {
            cfg: cfg.clone(),
            jobs: Vec::new(),
        }
    }

    /// Register a job with the service; returns its job id (submission
    /// order).  Admission control happens during [`JobService::run`]:
    /// the job is admitted when a concurrency slot is free at its
    /// virtual arrival time, queued while the pool is full, and
    /// rejected if the admission queue is at its bound.
    ///
    /// ```
    /// use difet::config::Config;
    /// use difet::coordinator::serve::{synthetic_jobs, JobService};
    /// use difet::metrics::Registry;
    ///
    /// let mut cfg = Config::new();
    /// cfg.serve.jobs = 2;
    /// let mut svc = JobService::new(&cfg);
    /// let mut ids = Vec::new();
    /// for job in synthetic_jobs(&cfg) {
    ///     ids.push(svc.submit(job));
    /// }
    /// assert_eq!(ids, vec![0, 1]);
    /// let report = svc.run(&Registry::new()).unwrap();
    /// assert_eq!(report.jobs.len(), 2);
    /// ```
    pub fn submit(&mut self, job: JobSpec) -> usize {
        self.jobs.push(job);
        self.jobs.len() - 1
    }

    pub fn submitted(&self) -> usize {
        self.jobs.len()
    }

    /// Drain every submitted job through the shared pool and report.
    pub fn run(&self, registry: &Registry) -> Result<ServeReport> {
        let wall = Stopwatch::start();
        let cfg = &self.cfg;
        let nodes = cfg.cluster.nodes;
        let slots = cfg.cluster.slots_per_node;
        let cost = CostModel::new(&cfg.cluster);

        // Layer-2 pre-flight, per job: reject unsatisfiable gate graphs
        // before a single worker slot spawns.
        for job in &self.jobs {
            let names: Vec<&str> = job.stages.iter().map(|s| s.name()).collect();
            let gate_defs: Vec<Vec<dag_check::GateDef>> = job
                .stages
                .iter()
                .map(|s| {
                    s.gates()
                        .iter()
                        .map(|g| dag_check::GateDef {
                            kind: match g {
                                Gate::Planned(_) => dag_check::GateKind::Planned,
                                Gate::Completed(_) => dag_check::GateKind::Completed,
                            },
                            target: match *g {
                                Gate::Planned(t) | Gate::Completed(t) => t,
                            },
                        })
                        .collect()
                })
                .collect();
            let issues = dag_check::validate_gates(&names, &gate_defs);
            if !issues.is_empty() {
                return Err(DifetError::Job(format!(
                    "job '{}': {}",
                    job.name,
                    issues.join("; ")
                )));
            }
        }

        // Tenant quotas: configured, or an even split of the pool.
        let n_tenants = self
            .jobs
            .iter()
            .map(|j| j.tenant + 1)
            .max()
            .unwrap_or(1)
            .max(cfg.serve.tenants)
            .max(1);
        let total_slots = (nodes * slots).max(1);
        let default_quota = (total_slots / n_tenants).max(1);
        let mut quotas = if cfg.serve.quotas.is_empty() {
            vec![default_quota; n_tenants]
        } else {
            cfg.serve.quotas.clone()
        };
        while quotas.len() < n_tenants {
            quotas.push(default_quota);
        }

        let arrival_ns: Vec<u64> = self.jobs.iter().map(|j| secs_to_ns(j.arrival_secs)).collect();
        let mut order: Vec<usize> = (0..self.jobs.len()).collect();
        order.sort_by_key(|&j| (arrival_ns[j], j));

        let exec = ServeExec {
            jobs: &self.jobs,
            order,
            arrival_ns,
            sched: Scheduler::new_fair(
                &cfg.scheduler,
                monotonic_clock(),
                &quotas,
                cfg.serve.preemption,
            ),
            state: Mutex::new(ServeState {
                jobs: self.jobs.iter().map(|j| JobRun::new(j.stages.len())).collect(),
                next_arrival: 0,
                running_jobs: 0,
                finished_jobs: 0,
                max_running: 0,
                max_queue_depth: 0,
                frontier_ns: 0,
            }),
            waiting: BoundedQueue::new(cfg.serve.queue_depth.max(1)),
            hb: cfg
                .scheduler
                .audit
                .then(|| self.jobs.iter().map(|_| HbChecker::new()).collect()),
            startup_ns: secs_to_ns(cost.job_startup()),
            overhead_ns: secs_to_ns(cost.task_overhead()),
            max_slot_ns: AtomicU64::new(0),
            nodes,
            slots_per_node: slots,
            max_concurrent: cfg.serve.max_concurrent_jobs.max(1),
        };

        // Admission bootstrap at the pool-startup frontier: startup is
        // paid ONCE here — every slot clock starts at `startup_ns` and
        // no per-job startup is ever charged again.
        exec.after_job_event(Some(exec.startup_ns))?;
        std::thread::scope(|scope| {
            for node in 0..nodes {
                for _slot in 0..slots {
                    let exec = &exec;
                    scope.spawn(move || exec.slot_loop(NodeId(node)));
                }
            }
        });
        if let Some(reason) = exec.sched.abort_reason() {
            return Err(DifetError::Job(reason));
        }
        // Layer-3 verdict, per job: each admitted job's sampled history
        // must be race-free even though the pool was shared.
        let mut hb_checks = 0u64;
        if let Some(hbs) = &exec.hb {
            for (j, hb) in hbs.iter().enumerate() {
                match hb.finish() {
                    Ok(c) => hb_checks += c,
                    Err(violations) => {
                        return Err(DifetError::Job(format!(
                            "job '{}' happens-before audit failed ({} violation(s)): {}",
                            self.jobs[j].name,
                            violations.len(),
                            violations.join("; ")
                        )))
                    }
                }
            }
            registry.counter("audit_hb_checks").add(hb_checks);
        }
        exec.report(wall.elapsed_secs(), &quotas, hb_checks, registry)
    }
}

// ---------------------------------------------------------------------------
// Synthetic workload (the `difet serve` simulation).
// ---------------------------------------------------------------------------

/// A synthetic DAG stage: unit `u` mixes its identity with its deps'
/// merged values into the job's sink — cheap wall-clock, meaningful
/// virtual cost, and a bit-exact output to compare solo vs shared.
struct SynthStage {
    name: &'static str,
    index: usize,
    gates: Vec<Gate>,
    unit_deps: Vec<Vec<UnitRef>>,
    preferred: Vec<Vec<NodeId>>,
    compute_ns: Vec<u64>,
    io_secs: Vec<f64>,
    salt: u64,
    fail_first: bool,
    sink: JobSink,
}

impl DagStage for SynthStage {
    fn name(&self) -> &'static str {
        self.name
    }

    fn gates(&self) -> Vec<Gate> {
        self.gates.clone()
    }

    fn plan(&self) -> Result<StagePlan> {
        Ok(StagePlan {
            units: self
                .unit_deps
                .iter()
                .zip(&self.preferred)
                .map(|(deps, pref)| UnitSpec {
                    deps: deps.clone(),
                    preferred_nodes: pref.clone(),
                })
                .collect(),
            plan_io_secs: 0.001,
        })
    }

    fn run_unit(
        &self,
        unit: usize,
        handle: &TaskHandle,
        _node: NodeId,
    ) -> Result<Option<UnitOutput>> {
        if handle.cancelled() {
            return Ok(None);
        }
        if self.fail_first && handle.attempt == 0 {
            return Err(DifetError::Job(format!(
                "{} unit {unit}: injected first-attempt fault",
                self.name
            )));
        }
        let mut v = mix(self.salt, mix(self.index as u64, unit as u64));
        {
            let merged = self.sink.lock().unwrap();
            for d in &self.unit_deps[unit] {
                let dep = merged.get(&(d.stage, d.unit)).copied().ok_or_else(|| {
                    DifetError::Job(format!(
                        "{} unit {unit}: dep ({},{}) observed before merge",
                        self.name, d.stage, d.unit
                    ))
                })?;
                v = mix(v, dep);
            }
        }
        Ok(Some(UnitOutput {
            payload: Box::new(v),
            compute_ns: self.compute_ns[unit],
            io_secs: self.io_secs[unit],
        }))
    }

    fn merge(&self, unit: usize, payload: Box<dyn Any + Send>) -> Result<()> {
        let v = *payload
            .downcast::<u64>()
            .map_err(|_| DifetError::Job("synthetic payload type mismatch".into()))?;
        self.sink.lock().unwrap().insert((self.index, unit), v);
        Ok(())
    }
}

/// Per-unit locality hints and virtual costs for one stage.
fn draw_units(rng: &mut Pcg32, nodes: usize, n: usize) -> (Vec<Vec<NodeId>>, Vec<u64>, Vec<f64>) {
    let mut pref = Vec::with_capacity(n);
    let mut comp = Vec::with_capacity(n);
    let mut io = Vec::with_capacity(n);
    for _ in 0..n {
        pref.push(vec![NodeId(rng.next_bounded(nodes.max(1) as u32) as usize)]);
        comp.push(secs_to_ns(0.05 + 0.35 * rng.next_f64()));
        io.push(0.02 * rng.next_f64());
    }
    (pref, comp, io)
}

fn synth_stage(
    name: &'static str,
    index: usize,
    gates: Vec<Gate>,
    unit_deps: Vec<Vec<UnitRef>>,
    rng: &mut Pcg32,
    nodes: usize,
    salt: u64,
    fail_first: bool,
    sink: &JobSink,
) -> Box<dyn DagStage + Send + Sync> {
    let (preferred, compute_ns, io_secs) = draw_units(rng, nodes, unit_deps.len());
    Box::new(SynthStage {
        name,
        index,
        gates,
        unit_deps,
        preferred,
        compute_ns,
        io_secs,
        salt,
        fail_first,
        sink: sink.clone(),
    })
}

type Shape = Vec<Box<dyn DagStage + Send + Sync>>;

/// extract: ingest fan-out → per-tile extraction (map-shaped).
fn extract_shape(rng: &mut Pcg32, nodes: usize, salt: u64, ff: bool, sink: &JobSink) -> Shape {
    let k = 2 + rng.next_bounded(3) as usize;
    let m = 2 + rng.next_bounded(4) as usize;
    let ingest: Vec<Vec<UnitRef>> = (0..k).map(|_| Vec::new()).collect();
    let tiles: Vec<Vec<UnitRef>> = (0..m)
        .map(|_| {
            vec![UnitRef {
                stage: 0,
                unit: rng.next_bounded(k as u32) as usize,
            }]
        })
        .collect();
    vec![
        synth_stage("ingest", 0, vec![], ingest, rng, nodes, salt, ff, sink),
        synth_stage("tiles", 1, vec![Gate::Planned(0)], tiles, rng, nodes, salt, ff, sink),
    ]
}

/// register: per-scene features → adjacent-pair matching (reduce-shaped).
fn register_shape(rng: &mut Pcg32, nodes: usize, salt: u64, ff: bool, sink: &JobSink) -> Shape {
    let k = 3 + rng.next_bounded(3) as usize;
    let features: Vec<Vec<UnitRef>> = (0..k).map(|_| Vec::new()).collect();
    let pairs: Vec<Vec<UnitRef>> = (0..k - 1)
        .map(|i| {
            vec![
                UnitRef { stage: 0, unit: i },
                UnitRef { stage: 0, unit: i + 1 },
            ]
        })
        .collect();
    vec![
        synth_stage("features", 0, vec![], features, rng, nodes, salt, ff, sink),
        synth_stage("pairs", 1, vec![Gate::Planned(0)], pairs, rng, nodes, salt, ff, sink),
    ]
}

/// stitch: tiles → canvas composition → intra-stage tree merge.
fn stitch_shape(rng: &mut Pcg32, nodes: usize, salt: u64, ff: bool, sink: &JobSink) -> Shape {
    let k = 4usize;
    let m = 2 + rng.next_bounded(3) as usize;
    let tiles: Vec<Vec<UnitRef>> = (0..k).map(|_| Vec::new()).collect();
    let canvas: Vec<Vec<UnitRef>> = (0..m)
        .map(|_| {
            let a = rng.next_bounded(k as u32) as usize;
            let b = (a + 1 + rng.next_bounded(k as u32 - 1) as usize) % k;
            vec![
                UnitRef { stage: 0, unit: a },
                UnitRef { stage: 0, unit: b },
            ]
        })
        .collect();
    // Tree merge over the canvas units: m cross-stage leaves, then
    // intra-stage parents pair up each level until one root remains.
    let mut tree: Vec<Vec<UnitRef>> = (0..m)
        .map(|i| vec![UnitRef { stage: 1, unit: i }])
        .collect();
    let mut level: Vec<usize> = (0..m).collect();
    while level.len() > 1 {
        let mut next = Vec::new();
        for pair in level.chunks(2) {
            if pair.len() == 1 {
                next.push(pair[0]);
                continue;
            }
            let parent = tree.len();
            tree.push(
                pair.iter()
                    .map(|&c| UnitRef { stage: 2, unit: c })
                    .collect(),
            );
            next.push(parent);
        }
        level = next;
    }
    vec![
        synth_stage("tiles", 0, vec![], tiles, rng, nodes, salt, ff, sink),
        synth_stage("canvas", 1, vec![Gate::Planned(0)], canvas, rng, nodes, salt, ff, sink),
        synth_stage("mergetree", 2, vec![Gate::Planned(1)], tree, rng, nodes, salt, ff, sink),
    ]
}

/// vectorize: label tiles → one global join, gated on stage COMPLETION
/// (plan-time consumes the whole upstream reduction).
fn vectorize_shape(rng: &mut Pcg32, nodes: usize, salt: u64, ff: bool, sink: &JobSink) -> Shape {
    let k = 3 + rng.next_bounded(4) as usize;
    let labels: Vec<Vec<UnitRef>> = (0..k).map(|_| Vec::new()).collect();
    let join: Vec<Vec<UnitRef>> = vec![(0..k).map(|i| UnitRef { stage: 0, unit: i }).collect()];
    vec![
        synth_stage("labels", 0, vec![], labels, rng, nodes, salt, ff, sink),
        synth_stage("vecjoin", 1, vec![Gate::Completed(0)], join, rng, nodes, salt, ff, sink),
    ]
}

/// The seeded synthetic workload `difet serve` drives: `serve.jobs`
/// jobs with Poisson-ish arrivals (exponential inter-arrival gaps of
/// mean `serve.mean_interarrival` on the virtual clock), tenants and
/// priorities drawn per job, and one of four DAG shapes each.
pub fn synthetic_jobs(cfg: &Config) -> Vec<JobSpec> {
    synthetic_jobs_with_faults(cfg, false)
}

/// Same workload with a first-attempt fault injected into EVERY unit —
/// the retry/preemption bit-parity property runs on this variant.
/// Outputs are identical to the fault-free workload (retries must not
/// change bits).
pub fn synthetic_jobs_with_faults(cfg: &Config, fail_first: bool) -> Vec<JobSpec> {
    let sc = &cfg.serve;
    let nodes = cfg.cluster.nodes.max(1);
    let tenants = sc.tenants.max(1) as u32;
    let mut rng = Pcg32::new(sc.seed, 7);
    let mut arrival = 0.0f64;
    let mut jobs = Vec::with_capacity(sc.jobs);
    for j in 0..sc.jobs {
        let u = rng.next_f64().clamp(1e-12, 1.0 - 1e-12);
        arrival += -sc.mean_interarrival * (1.0 - u).ln();
        let tenant = rng.next_bounded(tenants) as usize;
        let priority = 1 + rng.next_bounded(3) as u8;
        let salt = mix(sc.seed, j as u64);
        let sink: JobSink = Arc::new(Mutex::new(BTreeMap::new()));
        let (shape_name, stages) = match rng.next_bounded(4) {
            0 => ("extract", extract_shape(&mut rng, nodes, salt, fail_first, &sink)),
            1 => ("register", register_shape(&mut rng, nodes, salt, fail_first, &sink)),
            2 => ("stitch", stitch_shape(&mut rng, nodes, salt, fail_first, &sink)),
            _ => ("vectorize", vectorize_shape(&mut rng, nodes, salt, fail_first, &sink)),
        };
        jobs.push(JobSpec {
            name: format!("job{j:03}-{shape_name}"),
            tenant,
            priority,
            arrival_secs: arrival,
            stages,
            sink: Some(sink),
        });
    }
    jobs
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_cfg() -> Config {
        let mut cfg = Config::new();
        cfg.cluster.nodes = 1;
        cfg.cluster.slots_per_node = 1;
        cfg.serve.jobs = 4;
        cfg.serve.mean_interarrival = 0.5;
        cfg
    }

    /// One single-unit stage whose value is its job salt.
    fn one_unit_job(name: &str, tenant: usize, arrival_secs: f64, compute_secs: f64) -> JobSpec {
        let sink: JobSink = Arc::new(Mutex::new(BTreeMap::new()));
        let stage = Box::new(SynthStage {
            name: "solo",
            index: 0,
            gates: vec![],
            unit_deps: vec![vec![]],
            preferred: vec![vec![NodeId(0)]],
            compute_ns: vec![secs_to_ns(compute_secs)],
            io_secs: vec![0.0],
            salt: 11,
            fail_first: false,
            sink: sink.clone(),
        });
        JobSpec {
            name: name.to_string(),
            tenant,
            priority: 1,
            arrival_secs,
            stages: vec![stage],
            sink: Some(sink),
        }
    }

    #[test]
    fn pool_startup_is_paid_once_not_per_job() {
        let mut cfg = test_cfg();
        cfg.cluster.job_startup = 10.0;
        cfg.cluster.task_overhead = 0.0;
        let mut svc = JobService::new(&cfg);
        for i in 0..3 {
            svc.submit(one_unit_job(&format!("j{i}"), 0, 0.0, 1.0));
        }
        let report = svc.run(&Registry::new()).unwrap();
        assert_eq!(report.completed(), 3);
        // One 10s startup + 3×1s compute (+3ms plan io) on one slot; a
        // per-job startup would put the makespan past 30s.
        assert!(
            report.sim_seconds > 12.9 && report.sim_seconds < 14.0,
            "sim {} should reflect exactly one startup",
            report.sim_seconds
        );
        for job in &report.jobs {
            assert!(job.admit_secs >= 10.0, "admission waits for pool startup");
        }
    }

    #[test]
    fn admission_queue_rejects_past_bound() {
        let mut cfg = test_cfg();
        cfg.serve.max_concurrent_jobs = 1;
        cfg.serve.queue_depth = 1;
        let mut svc = JobService::new(&cfg);
        for i in 0..3 {
            svc.submit(one_unit_job(&format!("j{i}"), 0, 0.0, 0.5));
        }
        let report = svc.run(&Registry::new()).unwrap();
        assert_eq!(report.completed(), 2, "one running + one queued complete");
        assert_eq!(report.rejected(), 1, "the third due arrival is rejected");
        assert_eq!(report.max_queue_depth, 1);
        assert_eq!(report.max_running_jobs, 1);
        assert!(report.max_queue_depth <= cfg.serve.queue_depth as u64);
    }

    #[test]
    fn synthetic_workload_drains_with_fairness_and_audit() {
        let mut cfg = test_cfg();
        cfg.cluster.nodes = 2;
        cfg.cluster.slots_per_node = 2;
        cfg.serve.jobs = 12;
        let mut svc = JobService::new(&cfg);
        for job in synthetic_jobs(&cfg) {
            svc.submit(job);
        }
        let registry = Registry::new();
        let report = svc.run(&registry).unwrap();
        assert_eq!(report.completed() + report.rejected(), 12);
        assert!(report.fairness_ok(), "{} violations", report.fairness_violations);
        assert!(report.hb_checks > 0, "per-job hb audit must have sampled");
        assert!(report.max_running_jobs <= cfg.serve.max_concurrent_jobs as u64);
        let rendered = report.render();
        assert!(rendered.contains("fairness OK"));
        assert!(rendered.contains("tenant"));
        let snap = registry.render();
        assert!(snap.contains("tenant_jobs_submitted_0"));
        assert!(snap.contains("tenant_job_latency_seconds_0"));
    }

    #[test]
    fn single_slot_service_is_run_to_run_deterministic() {
        let run_once = || {
            let mut cfg = test_cfg();
            cfg.serve.jobs = 8;
            let mut svc = JobService::new(&cfg);
            for job in synthetic_jobs(&cfg) {
                svc.submit(job);
            }
            let report = svc.run(&Registry::new()).unwrap();
            let digests: Vec<Option<u64>> = report.jobs.iter().map(|j| j.digest).collect();
            let times: Vec<(u64, u64)> = report
                .jobs
                .iter()
                .map(|j| (secs_to_ns(j.admit_secs), secs_to_ns(j.finish_secs)))
                .collect();
            (digests, times, report.sim_seconds.to_bits())
        };
        assert_eq!(run_once(), run_once());
    }
}
