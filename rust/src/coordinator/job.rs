//! Job specification + result types.

use std::collections::BTreeMap;

use crate::dfs::NodeId;
use crate::features::matching::Translation;
use crate::features::{Descriptors, Keypoint};
use crate::mosaic::{BlendMode, OverlapStat};

/// Default bound on keypoints retained per image in final reports —
/// the single constant the distributed merge and the sequential baseline
/// both derive their truncation from (they used to disagree).
pub const DEFAULT_REPORT_KEYPOINTS: usize = 512;

/// Keypoints a mapper holds per image while tiles stream in: enough to
/// survive the final re-rank (`max` of the cap and the report bound).
pub fn mapper_retention(per_image_cap: Option<usize>, report_keypoints: usize) -> usize {
    per_image_cap.unwrap_or(report_keypoints).max(report_keypoints)
}

/// Keypoints retained in a final per-image census: the per-image cap when
/// it binds, bounded by the report limit.  Shared by the shuffle merge
/// and the sequential baseline so both paths keep identical lists.
pub fn final_retention(per_image_cap: Option<usize>, report_keypoints: usize) -> usize {
    per_image_cap.unwrap_or(usize::MAX).min(report_keypoints)
}

/// What to run: one algorithm over one HIB bundle in DFS.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Algorithm name (`harris` … `orb`).
    pub algorithm: String,
    /// DFS path of the input bundle.
    pub bundle_path: String,
    /// Per-image keypoint cap (OpenCV-default semantics; `None` = uncapped).
    pub per_image_cap: Option<usize>,
    /// Keep at most this many keypoints per image in the final report
    /// (memory bound for the merged result; census counts are unaffected).
    pub report_keypoints: usize,
    /// Write the annotated outputs back to DFS (the paper's mapper step 5
    /// "save FloatImage to hdfs with jpeg encoder").  Modeled+real write.
    pub write_output: bool,
}

impl JobSpec {
    pub fn new(algorithm: &str, bundle_path: &str) -> Self {
        JobSpec {
            algorithm: algorithm.to_string(),
            bundle_path: bundle_path.to_string(),
            per_image_cap: crate::per_image_cap(algorithm),
            report_keypoints: DEFAULT_REPORT_KEYPOINTS,
            write_output: true,
        }
    }
}

/// A fused job: several algorithms in ONE MapReduce pass over the bundle
/// (the split is read, decoded, tiled and gray-converted once; shared
/// detector intermediates are computed once per tile — see
/// [`crate::features::fused`]).  Emits one census per algorithm.
#[derive(Debug, Clone)]
pub struct FusedJobSpec {
    /// Algorithm names, each with its per-image cap (parallel vectors).
    pub algorithms: Vec<String>,
    pub per_image_caps: Vec<Option<usize>>,
    /// DFS path of the input bundle.
    pub bundle_path: String,
    pub report_keypoints: usize,
    pub write_output: bool,
    /// Carry descriptor payloads for the retained keypoints through the
    /// shuffle into the [`ImageCensus`]es (what a downstream registration
    /// job consumes).  Off by default: censuses-only jobs shouldn't pay
    /// the descriptor memory.
    pub keep_descriptors: bool,
}

impl FusedJobSpec {
    /// Paper-default caps (`crate::per_image_cap`) for each algorithm.
    pub fn new<S: AsRef<str>>(algorithms: &[S], bundle_path: &str) -> Self {
        FusedJobSpec {
            algorithms: algorithms.iter().map(|a| a.as_ref().to_string()).collect(),
            per_image_caps: algorithms
                .iter()
                .map(|a| crate::per_image_cap(a.as_ref()))
                .collect(),
            bundle_path: bundle_path.to_string(),
            report_keypoints: DEFAULT_REPORT_KEYPOINTS,
            write_output: true,
            keep_descriptors: false,
        }
    }
}

impl From<&JobSpec> for FusedJobSpec {
    /// A single-algorithm job is the degenerate fused job — `run_job` is
    /// implemented through this equivalence.
    fn from(spec: &JobSpec) -> Self {
        FusedJobSpec {
            algorithms: vec![spec.algorithm.clone()],
            per_image_caps: vec![spec.per_image_cap],
            bundle_path: spec.bundle_path.clone(),
            report_keypoints: spec.report_keypoints,
            write_output: spec.write_output,
            keep_descriptors: false,
        }
    }
}

/// One ingest work unit: decode one bundle record back into a scene
/// image.  The fifth [`super::scheduler::WorkItem`] shape — locality
/// points at the nodes holding the record's byte range of the bundle.
#[derive(Debug, Clone)]
pub struct IngestTask {
    /// Record index in the bundle (also the unit index).
    pub record: usize,
    /// Image id the record's header promises.
    pub image_id: u64,
    /// Byte range of the record within the bundle file.
    pub byte_start: u64,
    pub byte_end: u64,
    /// Nodes holding replicas of the record's blocks, best first.
    pub preferred_nodes: Vec<NodeId>,
}

impl super::scheduler::WorkItem for IngestTask {
    fn preferred_nodes(&self) -> &[NodeId] {
        &self.preferred_nodes
    }
}

/// One mapper's output for one image.
#[derive(Debug, Clone)]
pub struct MapOutput {
    pub image_id: u64,
    /// Exact tile-census sum for this image (pre-cap).
    pub raw_count: u64,
    /// Strongest keypoints (scene coordinates), possibly truncated.
    pub keypoints: Vec<Keypoint>,
    /// Number of descriptors computed (== keypoints for desc algorithms).
    pub descriptor_count: u64,
    /// Descriptor rows parallel to `keypoints` when the spec asked for
    /// them ([`FusedJobSpec::keep_descriptors`]); `Descriptors::None`
    /// otherwise.
    pub descriptors: Descriptors,
}

/// Final per-image result after the shuffle/merge stage.
#[derive(Debug, Clone, PartialEq)]
pub struct ImageCensus {
    pub image_id: u64,
    /// Census after the per-image cap (what Table 2 reports).
    pub count: u64,
    /// Pre-cap census (diagnostics; == count when no cap applies).
    pub raw_count: u64,
    pub keypoints: Vec<Keypoint>,
    /// Descriptor rows parallel to `keypoints` (present only when the
    /// job ran with `keep_descriptors`).
    pub descriptors: Descriptors,
}

/// Whole-job result: Table 1 cell (+ Table 2 rows via `images`).
#[derive(Debug, Clone)]
pub struct JobReport {
    pub algorithm: String,
    pub nodes: usize,
    pub image_count: usize,
    /// Simulated job time: startup + max-over-slots virtual time (the
    /// number comparable to the paper's Table 1).
    pub sim_seconds: f64,
    /// Host wall-clock actually spent (diagnostics only).
    pub wall_seconds: f64,
    /// Σ measured tile-compute seconds across all tasks.
    pub compute_seconds: f64,
    /// Σ modeled I/O seconds across all tasks.
    pub io_seconds: f64,
    pub images: Vec<ImageCensus>,
    /// Hadoop-style counters (tasks launched, data-local tasks, …).
    pub counters: BTreeMap<String, u64>,
}

impl JobReport {
    /// Total feature census (Table 2 cell).
    pub fn total_count(&self) -> u64 {
        self.images.iter().map(|i| i.count).sum()
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }
}

// ---------------------------------------------------------------------------
// Registration job: the reduce-shaped second stage.
// ---------------------------------------------------------------------------

/// What to register: scene pairs over one extracted census set.
///
/// The registration job is the system's first reduce-shaped workload: it
/// consumes the per-scene keypoints+descriptors a `keep_descriptors`
/// extraction produced, shuffles them into pair work units and recovers a
/// translation per pair (Sarı et al. 2018's stitching stage on the same
/// cluster).
#[derive(Debug, Clone)]
pub struct RegistrationSpec {
    /// Which algorithm's census/descriptors to match (must be a
    /// descriptor algorithm: sift/surf/brief/orb).
    pub algorithm: String,
    /// Explicit scene-id pairs, or `None` for every unordered pair.
    pub pairs: Option<Vec<(u64, u64)>>,
    /// Lowe ratio-test threshold.
    pub ratio: f32,
    /// RANSAC inlier tolerance in pixels.
    pub tolerance_px: f32,
    /// RANSAC hypothesis count per pair.
    pub ransac_iters: usize,
    /// Base seed; each pair derives its own via [`pair_seed`], so results
    /// are independent of which slot/attempt runs the pair.
    pub seed: u64,
    /// Pairs with fewer ratio-test matches than this report no
    /// translation (too little signal for a trustworthy consensus).
    pub min_matches: usize,
    /// DFS directory the shuffled per-scene feature files land in.
    pub feature_dir: String,
}

impl RegistrationSpec {
    pub fn new(algorithm: &str) -> Self {
        RegistrationSpec {
            algorithm: algorithm.to_string(),
            pairs: None,
            ratio: 0.85,
            tolerance_px: 3.0,
            ransac_iters: 256,
            seed: 7,
            min_matches: 8,
            feature_dir: "/shuffle/features".into(),
        }
    }
}

/// Deterministic per-pair RANSAC seed: mixes the job seed with both scene
/// ids (SplitMix64-style finalizer) so every pair draws an independent
/// stream and the distributed job matches the sequential baseline exactly.
pub fn pair_seed(base: u64, a: u64, b: u64) -> u64 {
    let mut z = base
        .wrapping_add(a.wrapping_mul(0x9E37_79B9_7F4A_7C15))
        .wrapping_add(b.wrapping_mul(0xBF58_476D_1CE4_E5B9));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// One reduce work unit: register scene `image_b` against `image_a`.
#[derive(Debug, Clone)]
pub struct PairTask {
    pub pair_id: usize,
    pub image_a: u64,
    pub image_b: u64,
    /// DFS paths of the two shuffled feature files.
    pub path_a: String,
    pub path_b: String,
    /// Nodes holding replicas of the feature files, best first.
    pub preferred_nodes: Vec<NodeId>,
}

impl super::scheduler::WorkItem for PairTask {
    fn preferred_nodes(&self) -> &[NodeId] {
        &self.preferred_nodes
    }
}

/// One registered pair (reduce output).
#[derive(Debug, Clone, PartialEq)]
pub struct PairResult {
    pub image_a: u64,
    pub image_b: u64,
    /// Ratio-test matches fed to RANSAC.
    pub matches: usize,
    /// Recovered translation taking A-coordinates to B-coordinates
    /// (`None`: fewer than `min_matches` matches, or no consensus).
    pub translation: Option<Translation>,
}

/// Whole registration-job result, shaped like [`JobReport`] so the same
/// reporting/accounting conventions apply.
#[derive(Debug, Clone)]
pub struct RegistrationReport {
    pub algorithm: String,
    pub nodes: usize,
    pub pair_count: usize,
    /// Simulated job time: startup + shuffle + max-over-slots virtual time.
    pub sim_seconds: f64,
    pub wall_seconds: f64,
    pub compute_seconds: f64,
    pub io_seconds: f64,
    /// Pair results in (image_a, image_b) order.
    pub pairs: Vec<PairResult>,
    pub counters: BTreeMap<String, u64>,
}

impl RegistrationReport {
    pub fn pair(&self, a: u64, b: u64) -> Option<&PairResult> {
        self.pairs.iter().find(|p| p.image_a == a && p.image_b == b)
    }

    /// Pairs that produced a consensus translation.
    pub fn registered_count(&self) -> usize {
        self.pairs.iter().filter(|p| p.translation.is_some()).count()
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }
}

// ---------------------------------------------------------------------------
// Mosaic job: canvas-tile compositing, the third work-item shape.
// ---------------------------------------------------------------------------

/// What to composite: blending policy and work-unit geometry for one
/// mosaic job over an aligned scene set.
#[derive(Debug, Clone)]
pub struct MosaicSpec {
    /// Overlap blending policy.
    pub blend: BlendMode,
    /// Canvas-tile edge in pixels (one work unit per tile).
    pub canvas_tile: usize,
    /// DFS directory the shuffled per-scene image files land in.
    pub scene_dir: String,
}

impl Default for MosaicSpec {
    fn default() -> Self {
        MosaicSpec {
            blend: BlendMode::Feather,
            canvas_tile: 512,
            scene_dir: "/shuffle/scenes".into(),
        }
    }
}

/// One mosaic work unit: render canvas rect `[row0, row1) × [col0, col1)`
/// from the scenes overlapping it.  The third [`super::scheduler::WorkItem`]
/// shape (after map splits and registration pairs) — locality points at
/// the nodes holding the overlapping scene files' replicas.
#[derive(Debug, Clone)]
pub struct CanvasTile {
    pub tile_id: usize,
    /// Half-open canvas rect (row0, row1, col0, col1).
    pub rect: [usize; 4],
    /// Scene ids overlapping the rect, ascending (the blend order).
    pub scene_ids: Vec<u64>,
    /// DFS paths of the overlapping scene files, parallel to `scene_ids`.
    pub scene_paths: Vec<String>,
    /// Nodes holding replicas of the scene files, best first.
    pub preferred_nodes: Vec<NodeId>,
}

impl super::scheduler::WorkItem for CanvasTile {
    fn preferred_nodes(&self) -> &[NodeId] {
        &self.preferred_nodes
    }
}

/// Whole mosaic-job result, shaped like [`JobReport`] so the same
/// reporting/accounting conventions apply; the composited pixels travel
/// separately (they are a whole image, not a table).
#[derive(Debug, Clone)]
pub struct MosaicReport {
    pub nodes: usize,
    pub scene_count: usize,
    pub canvas_width: usize,
    pub canvas_height: usize,
    pub tile_count: usize,
    pub blend: BlendMode,
    /// Simulated job time: startup + shuffle + max-over-slots virtual time.
    pub sim_seconds: f64,
    pub wall_seconds: f64,
    pub compute_seconds: f64,
    pub io_seconds: f64,
    /// Seam quality per overlapping scene pair (RMS RGB difference).
    pub overlaps: Vec<OverlapStat>,
    /// Largest alignment cycle residual, in pixels.
    pub max_cycle_residual: f64,
    /// RMS alignment cycle residual, in pixels.
    pub rms_cycle_residual: f64,
    pub counters: BTreeMap<String, u64>,
}

impl MosaicReport {
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Worst per-overlap seam RMS (0 when nothing overlaps).
    pub fn worst_overlap_rms(&self) -> f64 {
        self.overlaps.iter().map(|o| o.rms).fold(0.0, f64::max)
    }
}

// ---------------------------------------------------------------------------
// Vector job: band-tile labeling, the fourth work-item shape.
// ---------------------------------------------------------------------------

/// What to label: work-unit geometry and shuffle paths for one
/// object-extraction (vectorization) job over a segmented mask.
#[derive(Debug, Clone)]
pub struct VectorSpec {
    /// Rows per `LabelTile` work unit (full-width bands, so every unit's
    /// mask input is one contiguous DFS byte range).
    pub band_rows: usize,
    /// DFS path the shuffled mask raster lands in.
    pub mask_path: String,
    /// DFS directory the per-tile label files land in.
    pub labels_dir: String,
}

impl Default for VectorSpec {
    fn default() -> Self {
        VectorSpec {
            band_rows: 256,
            mask_path: "/shuffle/mask".into(),
            labels_dir: "/shuffle/labels".into(),
        }
    }
}

/// One labeling work unit: run tile-local connected-component labeling
/// over mask band `[row0, row1) × [0, width)`.  The fourth
/// [`super::scheduler::WorkItem`] shape (after map splits, registration
/// pairs and canvas tiles) — locality points at the nodes holding the
/// band's byte range of the shuffled mask file.
#[derive(Debug, Clone)]
pub struct LabelTile {
    pub tile_id: usize,
    /// Half-open mask rect (row0, row1, col0, col1); always full-width.
    pub rect: [usize; 4],
    /// Byte range of the band within the mask file (1 byte/pixel).
    pub byte_start: u64,
    pub byte_end: u64,
    /// DFS path of the shuffled mask raster.
    pub mask_path: String,
    /// DFS path this unit's encoded tile labels are written to.
    pub labels_path: String,
    /// Nodes holding replicas of the band's blocks, best first.
    pub preferred_nodes: Vec<NodeId>,
}

impl super::scheduler::WorkItem for LabelTile {
    fn preferred_nodes(&self) -> &[NodeId] {
        &self.preferred_nodes
    }
}

/// Whole vector-job result, shaped like [`JobReport`] so the same
/// reporting/accounting conventions apply; the merged label raster and
/// object table travel separately (they are data, not a table).
#[derive(Debug, Clone)]
pub struct VectorReport {
    pub nodes: usize,
    /// Mask geometry.
    pub width: usize,
    pub height: usize,
    pub tile_count: usize,
    /// Global objects after the union-find merge.
    pub object_count: usize,
    /// Foreground pixels in the mask.
    pub foreground_px: u64,
    /// Largest number of tile-local fragments merged into one object,
    /// minus one (0 = no object crossed a band boundary).
    pub max_merge_residual: u64,
    /// Union operations that joined distinct classes across seams.
    pub seam_unions: u64,
    /// Simulated job time: startup + shuffle + max-over-slots virtual time.
    pub sim_seconds: f64,
    pub wall_seconds: f64,
    pub compute_seconds: f64,
    pub io_seconds: f64,
    pub counters: BTreeMap<String, u64>,
}

impl VectorReport {
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_inherits_paper_caps() {
        assert_eq!(JobSpec::new("shi_tomasi", "/b").per_image_cap, Some(400));
        assert_eq!(JobSpec::new("orb", "/b").per_image_cap, Some(500));
        assert_eq!(JobSpec::new("harris", "/b").per_image_cap, None);
    }

    #[test]
    fn fused_spec_mirrors_per_algorithm_caps() {
        let f = FusedJobSpec::new(&["harris", "shi_tomasi", "orb"], "/b");
        assert_eq!(f.per_image_caps, vec![None, Some(400), Some(500)]);
        let single: FusedJobSpec = (&JobSpec::new("orb", "/b")).into();
        assert_eq!(single.algorithms, vec!["orb".to_string()]);
        assert_eq!(single.per_image_caps, vec![Some(500)]);
        assert_eq!(single.report_keypoints, DEFAULT_REPORT_KEYPOINTS);
    }

    #[test]
    fn retention_helpers_agree_on_paper_defaults() {
        // Capped algorithms: both paths retain exactly the cap.
        assert_eq!(final_retention(Some(400), 512), 400);
        assert_eq!(mapper_retention(Some(400), 512), 512);
        // Uncapped: both retain the report bound.
        assert_eq!(final_retention(None, 512), 512);
        assert_eq!(mapper_retention(None, 512), 512);
        // Cap above the report bound: final retention is the report bound
        // on BOTH paths (the divergence this helper fixed).
        assert_eq!(final_retention(Some(600), 512), 512);
        assert_eq!(mapper_retention(Some(600), 512), 600);
    }

    #[test]
    fn report_total_sums_capped_counts() {
        let mk = |id, count| ImageCensus {
            image_id: id,
            count,
            raw_count: count + 7,
            keypoints: vec![],
            descriptors: Descriptors::None,
        };
        let rep = JobReport {
            algorithm: "orb".into(),
            nodes: 2,
            image_count: 2,
            sim_seconds: 1.0,
            wall_seconds: 0.5,
            compute_seconds: 0.4,
            io_seconds: 0.3,
            images: vec![mk(0, 500), mk(1, 500)],
            counters: BTreeMap::new(),
        };
        assert_eq!(rep.total_count(), 1000);
        assert_eq!(rep.counter("nope"), 0);
    }

    #[test]
    fn pair_seed_is_deterministic_and_pair_sensitive() {
        assert_eq!(pair_seed(7, 0, 1), pair_seed(7, 0, 1));
        assert_ne!(pair_seed(7, 0, 1), pair_seed(7, 1, 0));
        assert_ne!(pair_seed(7, 0, 1), pair_seed(7, 0, 2));
        assert_ne!(pair_seed(7, 0, 1), pair_seed(8, 0, 1));
    }

    #[test]
    fn mosaic_report_defaults_and_worst_overlap() {
        let spec = MosaicSpec::default();
        assert_eq!(spec.blend, BlendMode::Feather);
        assert_eq!(spec.canvas_tile, 512);
        let rep = MosaicReport {
            nodes: 2,
            scene_count: 3,
            canvas_width: 100,
            canvas_height: 90,
            tile_count: 4,
            blend: spec.blend,
            sim_seconds: 1.0,
            wall_seconds: 0.1,
            compute_seconds: 0.05,
            io_seconds: 0.02,
            overlaps: vec![
                OverlapStat { a: 0, b: 1, area: 10, rms: 0.5 },
                OverlapStat { a: 1, b: 2, area: 4, rms: 2.25 },
            ],
            max_cycle_residual: 0.0,
            rms_cycle_residual: 0.0,
            counters: BTreeMap::new(),
        };
        assert_eq!(rep.worst_overlap_rms(), 2.25);
        assert_eq!(rep.counter("tiles"), 0);
    }

    #[test]
    fn registration_report_lookup_and_counts() {
        let t = Translation { d_row: 1.0, d_col: -2.0, inliers: 30 };
        let rep = RegistrationReport {
            algorithm: "orb".into(),
            nodes: 2,
            pair_count: 2,
            sim_seconds: 1.0,
            wall_seconds: 0.1,
            compute_seconds: 0.05,
            io_seconds: 0.02,
            pairs: vec![
                PairResult { image_a: 0, image_b: 1, matches: 50, translation: Some(t) },
                PairResult { image_a: 0, image_b: 2, matches: 3, translation: None },
            ],
            counters: BTreeMap::new(),
        };
        assert_eq!(rep.pair(0, 1).unwrap().matches, 50);
        assert!(rep.pair(1, 0).is_none());
        assert_eq!(rep.registered_count(), 1);
        assert_eq!(rep.counter("tasks"), 0);
    }

    #[test]
    fn vector_spec_defaults_and_report_counters() {
        let spec = VectorSpec::default();
        assert_eq!(spec.band_rows, 256);
        assert_eq!(spec.mask_path, "/shuffle/mask");
        assert_eq!(spec.labels_dir, "/shuffle/labels");
        let rep = VectorReport {
            nodes: 2,
            width: 100,
            height: 80,
            tile_count: 4,
            object_count: 7,
            foreground_px: 1234,
            max_merge_residual: 2,
            seam_unions: 5,
            sim_seconds: 1.0,
            wall_seconds: 0.1,
            compute_seconds: 0.05,
            io_seconds: 0.02,
            counters: BTreeMap::new(),
        };
        assert_eq!(rep.counter("tiles"), 0);
        assert_eq!(rep.max_merge_residual, 2);
    }
}
