//! Job specification + result types.

use std::collections::BTreeMap;

use crate::features::Keypoint;

/// Default bound on keypoints retained per image in final reports —
/// the single constant the distributed merge and the sequential baseline
/// both derive their truncation from (they used to disagree).
pub const DEFAULT_REPORT_KEYPOINTS: usize = 512;

/// Keypoints a mapper holds per image while tiles stream in: enough to
/// survive the final re-rank (`max` of the cap and the report bound).
pub fn mapper_retention(per_image_cap: Option<usize>, report_keypoints: usize) -> usize {
    per_image_cap.unwrap_or(report_keypoints).max(report_keypoints)
}

/// Keypoints retained in a final per-image census: the per-image cap when
/// it binds, bounded by the report limit.  Shared by the shuffle merge
/// and the sequential baseline so both paths keep identical lists.
pub fn final_retention(per_image_cap: Option<usize>, report_keypoints: usize) -> usize {
    per_image_cap.unwrap_or(usize::MAX).min(report_keypoints)
}

/// What to run: one algorithm over one HIB bundle in DFS.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Algorithm name (`harris` … `orb`).
    pub algorithm: String,
    /// DFS path of the input bundle.
    pub bundle_path: String,
    /// Per-image keypoint cap (OpenCV-default semantics; `None` = uncapped).
    pub per_image_cap: Option<usize>,
    /// Keep at most this many keypoints per image in the final report
    /// (memory bound for the merged result; census counts are unaffected).
    pub report_keypoints: usize,
    /// Write the annotated outputs back to DFS (the paper's mapper step 5
    /// "save FloatImage to hdfs with jpeg encoder").  Modeled+real write.
    pub write_output: bool,
}

impl JobSpec {
    pub fn new(algorithm: &str, bundle_path: &str) -> Self {
        JobSpec {
            algorithm: algorithm.to_string(),
            bundle_path: bundle_path.to_string(),
            per_image_cap: crate::per_image_cap(algorithm),
            report_keypoints: DEFAULT_REPORT_KEYPOINTS,
            write_output: true,
        }
    }
}

/// A fused job: several algorithms in ONE MapReduce pass over the bundle
/// (the split is read, decoded, tiled and gray-converted once; shared
/// detector intermediates are computed once per tile — see
/// [`crate::features::fused`]).  Emits one census per algorithm.
#[derive(Debug, Clone)]
pub struct FusedJobSpec {
    /// Algorithm names, each with its per-image cap (parallel vectors).
    pub algorithms: Vec<String>,
    pub per_image_caps: Vec<Option<usize>>,
    /// DFS path of the input bundle.
    pub bundle_path: String,
    pub report_keypoints: usize,
    pub write_output: bool,
}

impl FusedJobSpec {
    /// Paper-default caps (`crate::per_image_cap`) for each algorithm.
    pub fn new<S: AsRef<str>>(algorithms: &[S], bundle_path: &str) -> Self {
        FusedJobSpec {
            algorithms: algorithms.iter().map(|a| a.as_ref().to_string()).collect(),
            per_image_caps: algorithms
                .iter()
                .map(|a| crate::per_image_cap(a.as_ref()))
                .collect(),
            bundle_path: bundle_path.to_string(),
            report_keypoints: DEFAULT_REPORT_KEYPOINTS,
            write_output: true,
        }
    }
}

impl From<&JobSpec> for FusedJobSpec {
    /// A single-algorithm job is the degenerate fused job — `run_job` is
    /// implemented through this equivalence.
    fn from(spec: &JobSpec) -> Self {
        FusedJobSpec {
            algorithms: vec![spec.algorithm.clone()],
            per_image_caps: vec![spec.per_image_cap],
            bundle_path: spec.bundle_path.clone(),
            report_keypoints: spec.report_keypoints,
            write_output: spec.write_output,
        }
    }
}

/// One mapper's output for one image.
#[derive(Debug, Clone)]
pub struct MapOutput {
    pub image_id: u64,
    /// Exact tile-census sum for this image (pre-cap).
    pub raw_count: u64,
    /// Strongest keypoints (scene coordinates), possibly truncated.
    pub keypoints: Vec<Keypoint>,
    /// Number of descriptors computed (== keypoints for desc algorithms).
    pub descriptor_count: u64,
}

/// Final per-image result after the shuffle/merge stage.
#[derive(Debug, Clone)]
pub struct ImageCensus {
    pub image_id: u64,
    /// Census after the per-image cap (what Table 2 reports).
    pub count: u64,
    /// Pre-cap census (diagnostics; == count when no cap applies).
    pub raw_count: u64,
    pub keypoints: Vec<Keypoint>,
}

/// Whole-job result: Table 1 cell (+ Table 2 rows via `images`).
#[derive(Debug, Clone)]
pub struct JobReport {
    pub algorithm: String,
    pub nodes: usize,
    pub image_count: usize,
    /// Simulated job time: startup + max-over-slots virtual time (the
    /// number comparable to the paper's Table 1).
    pub sim_seconds: f64,
    /// Host wall-clock actually spent (diagnostics only).
    pub wall_seconds: f64,
    /// Σ measured tile-compute seconds across all tasks.
    pub compute_seconds: f64,
    /// Σ modeled I/O seconds across all tasks.
    pub io_seconds: f64,
    pub images: Vec<ImageCensus>,
    /// Hadoop-style counters (tasks launched, data-local tasks, …).
    pub counters: BTreeMap<String, u64>,
}

impl JobReport {
    /// Total feature census (Table 2 cell).
    pub fn total_count(&self) -> u64 {
        self.images.iter().map(|i| i.count).sum()
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_inherits_paper_caps() {
        assert_eq!(JobSpec::new("shi_tomasi", "/b").per_image_cap, Some(400));
        assert_eq!(JobSpec::new("orb", "/b").per_image_cap, Some(500));
        assert_eq!(JobSpec::new("harris", "/b").per_image_cap, None);
    }

    #[test]
    fn fused_spec_mirrors_per_algorithm_caps() {
        let f = FusedJobSpec::new(&["harris", "shi_tomasi", "orb"], "/b");
        assert_eq!(f.per_image_caps, vec![None, Some(400), Some(500)]);
        let single: FusedJobSpec = (&JobSpec::new("orb", "/b")).into();
        assert_eq!(single.algorithms, vec!["orb".to_string()]);
        assert_eq!(single.per_image_caps, vec![Some(500)]);
        assert_eq!(single.report_keypoints, DEFAULT_REPORT_KEYPOINTS);
    }

    #[test]
    fn retention_helpers_agree_on_paper_defaults() {
        // Capped algorithms: both paths retain exactly the cap.
        assert_eq!(final_retention(Some(400), 512), 400);
        assert_eq!(mapper_retention(Some(400), 512), 512);
        // Uncapped: both retain the report bound.
        assert_eq!(final_retention(None, 512), 512);
        assert_eq!(mapper_retention(None, 512), 512);
        // Cap above the report bound: final retention is the report bound
        // on BOTH paths (the divergence this helper fixed).
        assert_eq!(final_retention(Some(600), 512), 512);
        assert_eq!(mapper_retention(Some(600), 512), 600);
    }

    #[test]
    fn report_total_sums_capped_counts() {
        let mk = |id, count| ImageCensus {
            image_id: id,
            count,
            raw_count: count + 7,
            keypoints: vec![],
        };
        let rep = JobReport {
            algorithm: "orb".into(),
            nodes: 2,
            image_count: 2,
            sim_seconds: 1.0,
            wall_seconds: 0.5,
            compute_seconds: 0.4,
            io_seconds: 0.3,
            images: vec![mk(0, 500), mk(1, 500)],
            counters: BTreeMap::new(),
        };
        assert_eq!(rep.total_count(), 1000);
        assert_eq!(rep.counter("nope"), 0);
    }
}
