//! Mosaicking: pairwise registrations → one seamless composite image.
//!
//! The subsystem the paper's authors built next ("An Approach For
//! Stitching Satellite Images In A Bigdata MapReduce Framework", Sarı,
//! Eken, Sayar 2018): the registration job's per-pair translations are
//! lifted to per-scene absolute positions by a global least-squares
//! solve ([`align`]), scenes are placed on an integer canvas and blended
//! with distance-feathered weights ([`composite`]), and the canvas is
//! rendered either sequentially or as tile-shaped work units on the
//! generic coordinator [`crate::coordinator::Scheduler`]
//! ([`crate::coordinator::run_mosaic_job`]) — byte-identically, which is
//! asserted end to end by `rust/tests/mosaic_e2e.rs`.
//!
//! The driver-facing flow lives in [`crate::pipeline::stitch`]:
//! ingest → register → align → composite.

pub mod align;
pub mod composite;

pub use align::{
    measurements_from_pairs, prepare_alignment, solve_alignment, AlignOptions, AlignProblem,
    ComponentSolution, EdgeResidual, GlobalAlignment, PairMeasurement,
};
pub use composite::{
    composite_rect_while, composite_sequential, layout, overlap_stats, scenes_in_rect,
    tile_rects, BlendMode, Canvas, OverlapStat, Placement,
};
