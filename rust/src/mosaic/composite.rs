//! Mosaic compositing: aligned scenes → one blended canvas.
//!
//! Scenes are placed on an integer canvas grid (positions from the
//! [`super::align`] solver, rounded to the nearest pixel — the
//! registration model is translation-only, so sub-pixel resampling would
//! add nothing but blur) and blended per pixel.  The per-pixel loop is
//! the whole determinism story: each canvas pixel is computed from
//! scratch from the scenes covering it, in ascending scene-id order,
//! with f64 accumulation — so any rectangle of the canvas composites to
//! the same bytes whether it is rendered by one thread
//! ([`composite_sequential`]) or as a tile-shaped work unit of the
//! distributed job ([`crate::coordinator::run_mosaic_job`]).  Scenes
//! that do not cover a pixel contribute nothing, which is why a tile
//! worker only needs the scenes overlapping its rectangle.

use std::collections::BTreeMap;

use crate::imagery::Rgba8Image;
use crate::util::{DifetError, Result};

use super::align::GlobalAlignment;

/// Overlap blending policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlendMode {
    /// Distance-feathered weights: each scene's contribution is its
    /// pixel's distance to the nearest scene edge, so seams fade linearly
    /// (the default, and the mode the paper's stitching follow-up uses).
    Feather,
    /// Unweighted mean of all covering scenes.
    Average,
    /// First covering scene (ascending id) wins — hard seams, useful as
    /// a diagnostic for misalignment.
    First,
}

impl BlendMode {
    pub fn parse(name: &str) -> Result<BlendMode> {
        match name {
            "feather" => Ok(BlendMode::Feather),
            "average" => Ok(BlendMode::Average),
            "first" => Ok(BlendMode::First),
            other => Err(DifetError::Config(format!(
                "unknown blend mode {other:?} (known: feather, average, first)"
            ))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            BlendMode::Feather => "feather",
            BlendMode::Average => "average",
            BlendMode::First => "first",
        }
    }
}

/// One scene's placement on the canvas (canvas-relative, non-negative).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Placement {
    pub id: u64,
    pub row0: usize,
    pub col0: usize,
    pub width: usize,
    pub height: usize,
}

impl Placement {
    /// Half-open canvas rect `[row0, row1) × [col0, col1)`.
    pub fn rect(&self) -> [usize; 4] {
        [self.row0, self.row0 + self.height, self.col0, self.col0 + self.width]
    }
}

/// The mosaic canvas: its size and every scene's placement, sorted by
/// scene id (the blend order).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Canvas {
    pub width: usize,
    pub height: usize,
    pub placements: Vec<Placement>,
}

/// Do two half-open rects `[r0, r1) × [c0, c1)` intersect, and where?
fn intersect(a: [usize; 4], b: [usize; 4]) -> Option<[usize; 4]> {
    let r0 = a[0].max(b[0]);
    let r1 = a[1].min(b[1]);
    let c0 = a[2].max(b[2]);
    let c1 = a[3].min(b[3]);
    (r0 < r1 && c0 < c1).then_some([r0, r1, c0, c1])
}

/// Lay out the canvas: round solved positions to integer pixels, shift so
/// the top-left-most scene corner is (0, 0), compute the bounding box.
/// `dims` maps scene id → (width, height); every dims entry must have a
/// solved position.
pub fn layout(alignment: &GlobalAlignment, dims: &[(u64, usize, usize)]) -> Result<Canvas> {
    if dims.is_empty() {
        return Err(DifetError::Job("mosaic layout: no scenes".into()));
    }
    let mut sorted: Vec<(u64, usize, usize)> = dims.to_vec();
    sorted.sort_unstable_by_key(|&(id, _, _)| id);
    for w in sorted.windows(2) {
        if w[0].0 == w[1].0 {
            return Err(DifetError::Job(format!("duplicate scene id {}", w[0].0)));
        }
    }
    let mut px: Vec<(u64, i64, i64, usize, usize)> = Vec::with_capacity(sorted.len());
    for &(id, width, height) in &sorted {
        let &(r, c) = alignment.positions.get(&id).ok_or_else(|| {
            DifetError::Job(format!("scene {id} has no solved position"))
        })?;
        if !r.is_finite() || !c.is_finite() {
            return Err(DifetError::Job(format!("scene {id} position is not finite")));
        }
        px.push((id, r.round() as i64, c.round() as i64, width, height));
    }
    let min_r = px.iter().map(|p| p.1).min().unwrap();
    let min_c = px.iter().map(|p| p.2).min().unwrap();
    let placements: Vec<Placement> = px
        .iter()
        .map(|&(id, r, c, width, height)| Placement {
            id,
            row0: (r - min_r) as usize,
            col0: (c - min_c) as usize,
            width,
            height,
        })
        .collect();
    let height = placements.iter().map(|p| p.row0 + p.height).max().unwrap();
    let width = placements.iter().map(|p| p.col0 + p.width).max().unwrap();
    Ok(Canvas { width, height, placements })
}

/// Feather weight of local pixel (r, c) in a w×h scene: distance (in
/// pixels, 1-based) to the nearest scene edge.
#[inline]
fn feather_weight(r: usize, c: usize, w: usize, h: usize) -> f64 {
    let wr = (r + 1).min(h - r);
    let wc = (c + 1).min(w - c);
    wr.min(wc) as f64
}

/// Composite one canvas rect `[row0, row1) × [col0, col1)` from the given
/// placements, calling `keep_going(rows_done, rows_total)` after every
/// row (returning `false` abandons the render and yields `None` — the
/// cooperative-cancellation hook a losing speculative twin dies through).
///
/// `scenes` maps scene id → pixels; only placements whose scene is
/// present AND whose rect intersects `rect` contribute, and contributions
/// accumulate in ascending placement (scene-id) order, so the output
/// bytes are independent of how the canvas is partitioned into rects.
pub fn composite_rect_while(
    canvas: &Canvas,
    scenes: &BTreeMap<u64, &Rgba8Image>,
    blend: BlendMode,
    rect: [usize; 4],
    keep_going: &mut dyn FnMut(usize, usize) -> bool,
) -> Result<Option<Vec<u8>>> {
    let [row0, row1, col0, col1] = rect;
    if row1 > canvas.height || col1 > canvas.width || row0 > row1 || col0 > col1 {
        return Err(DifetError::Job(format!(
            "composite rect {rect:?} outside {}×{} canvas",
            canvas.height, canvas.width
        )));
    }
    // Placements touching this rect, with their pixel buffers.
    let mut active: Vec<(&Placement, &Rgba8Image)> = Vec::new();
    for p in &canvas.placements {
        if intersect(p.rect(), rect).is_none() {
            continue;
        }
        let img = scenes.get(&p.id).copied().ok_or_else(|| {
            DifetError::Job(format!("scene {} overlaps rect {rect:?} but was not provided", p.id))
        })?;
        if (img.width, img.height) != (p.width, p.height) {
            return Err(DifetError::Job(format!(
                "scene {}: placement says {}×{}, image is {}×{}",
                p.id, p.width, p.height, img.width, img.height
            )));
        }
        active.push((p, img));
    }

    let (rows, cols) = (row1 - row0, col1 - col0);
    let mut out = vec![0u8; rows * cols * 4];
    for (done, row) in (row0..row1).enumerate() {
        for col in col0..col1 {
            let mut acc = [0.0f64; 3];
            let mut acc_w = 0.0f64;
            for &(p, img) in &active {
                if row < p.row0 || col < p.col0 {
                    continue;
                }
                let (lr, lc) = (row - p.row0, col - p.col0);
                if lr >= p.height || lc >= p.width {
                    continue;
                }
                let [r, g, b, _] = img.get(lr, lc);
                let w = match blend {
                    BlendMode::Feather => feather_weight(lr, lc, p.width, p.height),
                    BlendMode::Average => 1.0,
                    BlendMode::First => 1.0,
                };
                acc[0] += w * r as f64;
                acc[1] += w * g as f64;
                acc[2] += w * b as f64;
                acc_w += w;
                if blend == BlendMode::First {
                    break;
                }
            }
            let base = ((row - row0) * cols + (col - col0)) * 4;
            if acc_w > 0.0 {
                for ch in 0..3 {
                    out[base + ch] = (acc[ch] / acc_w).round().clamp(0.0, 255.0) as u8;
                }
                out[base + 3] = 255;
            }
        }
        if !keep_going(done + 1, rows) {
            return Ok(None);
        }
    }
    Ok(Some(out))
}

/// Single-threaded whole-canvas composite — the baseline the distributed
/// job must reproduce byte for byte (`rust/tests/mosaic_e2e.rs`).
pub fn composite_sequential(
    canvas: &Canvas,
    scenes: &BTreeMap<u64, &Rgba8Image>,
    blend: BlendMode,
) -> Result<Rgba8Image> {
    let rect = [0, canvas.height, 0, canvas.width];
    let data = composite_rect_while(canvas, scenes, blend, rect, &mut |_, _| true)?
        .expect("uncancellable composite cannot be cancelled");
    Ok(Rgba8Image { width: canvas.width, height: canvas.height, data })
}

/// Seam quality of one scene overlap: RMS per-channel RGB difference over
/// the intersection of the two placements (0 when the aligned scenes
/// agree exactly where they overlap).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OverlapStat {
    pub a: u64,
    pub b: u64,
    /// Overlap area in pixels.
    pub area: usize,
    /// RMS RGB difference over the overlap, in 8-bit DN units.
    pub rms: f64,
}

/// Compute [`OverlapStat`]s for every overlapping placement pair (a < b
/// by id order).
pub fn overlap_stats(
    canvas: &Canvas,
    scenes: &BTreeMap<u64, &Rgba8Image>,
) -> Result<Vec<OverlapStat>> {
    let mut out = Vec::new();
    for (i, pa) in canvas.placements.iter().enumerate() {
        for pb in &canvas.placements[i + 1..] {
            let Some([r0, r1, c0, c1]) = intersect(pa.rect(), pb.rect()) else {
                continue;
            };
            let get = |p: &Placement| {
                scenes.get(&p.id).copied().ok_or_else(|| {
                    DifetError::Job(format!("scene {} missing for overlap stats", p.id))
                })
            };
            let (ia, ib) = (get(pa)?, get(pb)?);
            let mut sum_sq = 0.0f64;
            for row in r0..r1 {
                for col in c0..c1 {
                    let x = ia.get(row - pa.row0, col - pa.col0);
                    let y = ib.get(row - pb.row0, col - pb.col0);
                    for ch in 0..3 {
                        let d = x[ch] as f64 - y[ch] as f64;
                        sum_sq += d * d;
                    }
                }
            }
            let area = (r1 - r0) * (c1 - c0);
            out.push(OverlapStat {
                a: pa.id,
                b: pb.id,
                area,
                rms: (sum_sq / (area * 3) as f64).sqrt(),
            });
        }
    }
    Ok(out)
}

/// Canvas tile rects of edge `tile` (row-major), covering the canvas.
pub fn tile_rects(canvas: &Canvas, tile: usize) -> Vec<[usize; 4]> {
    let tile = tile.max(1);
    let mut out = Vec::new();
    let mut r = 0;
    while r < canvas.height {
        let r1 = (r + tile).min(canvas.height);
        let mut c = 0;
        while c < canvas.width {
            let c1 = (c + tile).min(canvas.width);
            out.push([r, r1, c, c1]);
            c = c1;
        }
        r = r1;
    }
    out
}

/// Scene ids (ascending) whose placements intersect `rect`.
pub fn scenes_in_rect(canvas: &Canvas, rect: [usize; 4]) -> Vec<u64> {
    canvas
        .placements
        .iter()
        .filter(|p| intersect(p.rect(), rect).is_some())
        .map(|p| p.id)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mosaic::align::{solve_alignment, AlignOptions, PairMeasurement};

    fn flat(w: usize, h: usize, v: u8) -> Rgba8Image {
        Rgba8Image { width: w, height: h, data: vec![v; w * h * 4] }
    }

    fn two_scene_canvas() -> (Canvas, Rgba8Image, Rgba8Image) {
        // Scene 1 sits 4 px right/down of scene 0; both 8×8.
        let al = solve_alignment(
            &[0, 1],
            &[PairMeasurement { a: 0, b: 1, d_row: -4.0, d_col: -4.0, weight: 1.0 }],
            AlignOptions::default(),
        )
        .unwrap();
        let canvas = layout(&al, &[(0, 8, 8), (1, 8, 8)]).unwrap();
        (canvas, flat(8, 8, 100), flat(8, 8, 200))
    }

    #[test]
    fn layout_normalizes_to_origin_and_bounds() {
        let (canvas, _, _) = two_scene_canvas();
        assert_eq!((canvas.width, canvas.height), (12, 12));
        assert_eq!(canvas.placements[0], Placement { id: 0, row0: 0, col0: 0, width: 8, height: 8 });
        assert_eq!(canvas.placements[1], Placement { id: 1, row0: 4, col0: 4, width: 8, height: 8 });
    }

    #[test]
    fn layout_handles_negative_positions() {
        // Scene 1 placed up-left of the anchor: everything shifts.
        let al = solve_alignment(
            &[0, 1],
            &[PairMeasurement { a: 0, b: 1, d_row: 3.0, d_col: 5.0, weight: 1.0 }],
            AlignOptions::default(),
        )
        .unwrap();
        let canvas = layout(&al, &[(0, 10, 10), (1, 10, 10)]).unwrap();
        assert_eq!(canvas.placements[0].row0, 3);
        assert_eq!(canvas.placements[0].col0, 5);
        assert_eq!(canvas.placements[1].row0, 0);
        assert_eq!(canvas.placements[1].col0, 0);
        assert_eq!((canvas.height, canvas.width), (13, 15));
    }

    #[test]
    fn composite_covers_blends_and_leaves_gaps_transparent() {
        let (canvas, s0, s1) = two_scene_canvas();
        let scenes: BTreeMap<u64, &Rgba8Image> = [(0u64, &s0), (1u64, &s1)].into();
        let m = composite_sequential(&canvas, &scenes, BlendMode::Feather).unwrap();
        // Exclusive regions take their scene's value.
        assert_eq!(m.get(0, 0), [100, 100, 100, 255]);
        assert_eq!(m.get(11, 11), [200, 200, 200, 255]);
        // Overlap blends strictly between the two.
        let mid = m.get(5, 5);
        assert!(mid[0] > 100 && mid[0] < 200, "overlap pixel {:?}", mid);
        assert_eq!(mid[3], 255);
        // The corners off both scenes stay transparent black.
        assert_eq!(m.get(0, 11), [0, 0, 0, 0]);
        assert_eq!(m.get(11, 0), [0, 0, 0, 0]);
    }

    #[test]
    fn first_mode_lets_the_lowest_id_win() {
        let (canvas, s0, s1) = two_scene_canvas();
        let scenes: BTreeMap<u64, &Rgba8Image> = [(0u64, &s0), (1u64, &s1)].into();
        let m = composite_sequential(&canvas, &scenes, BlendMode::First).unwrap();
        assert_eq!(m.get(5, 5), [100, 100, 100, 255], "scene 0 must win the overlap");
        assert_eq!(m.get(9, 9), [200, 200, 200, 255]);
    }

    #[test]
    fn tiled_composite_equals_whole_canvas_composite() {
        let (canvas, s0, s1) = two_scene_canvas();
        let scenes: BTreeMap<u64, &Rgba8Image> = [(0u64, &s0), (1u64, &s1)].into();
        for blend in [BlendMode::Feather, BlendMode::Average, BlendMode::First] {
            let whole = composite_sequential(&canvas, &scenes, blend).unwrap();
            for tile in [1usize, 3, 5, 12, 100] {
                let mut assembled = vec![0u8; whole.data.len()];
                for rect in tile_rects(&canvas, tile) {
                    let px =
                        composite_rect_while(&canvas, &scenes, blend, rect, &mut |_, _| true)
                            .unwrap()
                            .unwrap();
                    let [r0, r1, c0, c1] = rect;
                    let cols = c1 - c0;
                    for (i, row) in (r0..r1).enumerate() {
                        let dst = (row * canvas.width + c0) * 4;
                        let src = i * cols * 4;
                        assembled[dst..dst + cols * 4]
                            .copy_from_slice(&px[src..src + cols * 4]);
                    }
                }
                assert_eq!(assembled, whole.data, "blend {blend:?} tile {tile} diverged");
            }
        }
    }

    #[test]
    fn cancellation_stops_mid_rect() {
        let (canvas, s0, s1) = two_scene_canvas();
        let scenes: BTreeMap<u64, &Rgba8Image> = [(0u64, &s0), (1u64, &s1)].into();
        let mut rows = 0usize;
        let out = composite_rect_while(
            &canvas,
            &scenes,
            BlendMode::Feather,
            [0, 12, 0, 12],
            &mut |done, _| {
                rows = done;
                done < 5
            },
        )
        .unwrap();
        assert!(out.is_none());
        assert_eq!(rows, 5);
    }

    #[test]
    fn overlap_stats_measure_agreement() {
        let (canvas, s0, _) = two_scene_canvas();
        // Identical content in the overlap → RMS 0.
        let s1 = flat(8, 8, 100);
        let scenes: BTreeMap<u64, &Rgba8Image> = [(0u64, &s0), (1u64, &s1)].into();
        let stats = overlap_stats(&canvas, &scenes).unwrap();
        assert_eq!(stats.len(), 1);
        assert_eq!((stats[0].a, stats[0].b, stats[0].area), (0, 1, 16));
        assert_eq!(stats[0].rms, 0.0);
        // Constant 100-DN disagreement → RMS exactly 100.
        let s2 = flat(8, 8, 200);
        let scenes: BTreeMap<u64, &Rgba8Image> = [(0u64, &s0), (1u64, &s2)].into();
        let stats = overlap_stats(&canvas, &scenes).unwrap();
        assert!((stats[0].rms - 100.0).abs() < 1e-9);
    }

    #[test]
    fn composite_rejects_missing_scenes_and_bad_rects() {
        let (canvas, s0, _) = two_scene_canvas();
        let scenes: BTreeMap<u64, &Rgba8Image> = [(0u64, &s0)].into();
        assert!(composite_sequential(&canvas, &scenes, BlendMode::Feather).is_err());
        let full: BTreeMap<u64, &Rgba8Image> = BTreeMap::new();
        assert!(
            composite_rect_while(&canvas, &full, BlendMode::Feather, [0, 99, 0, 1], &mut |_, _| {
                true
            })
            .is_err(),
            "rect outside the canvas must be rejected"
        );
    }

    #[test]
    fn tile_rects_cover_exactly() {
        let canvas = Canvas { width: 10, height: 7, placements: vec![] };
        let rects = tile_rects(&canvas, 4);
        assert_eq!(rects.len(), 6);
        let area: usize = rects.iter().map(|[r0, r1, c0, c1]| (r1 - r0) * (c1 - c0)).sum();
        assert_eq!(area, 70);
        assert!(rects.iter().all(|&[r0, r1, c0, c1]| r0 < r1 && c0 < c1 && r1 <= 7 && c1 <= 10));
    }

    #[test]
    fn blend_mode_parse_roundtrip() {
        for b in [BlendMode::Feather, BlendMode::Average, BlendMode::First] {
            assert_eq!(BlendMode::parse(b.name()).unwrap(), b);
        }
        assert!(BlendMode::parse("poisson").is_err());
    }
}
