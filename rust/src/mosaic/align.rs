//! Global alignment: pairwise translations → per-scene absolute positions.
//!
//! The registration job leaves a *graph*: scenes are vertices, registered
//! pairs are edges measuring `pos_a − pos_b` (a translation taking
//! A-coordinates to B-coordinates is exactly the difference of the two
//! scenes' canvas origins).  Mosaicking needs one absolute position per
//! scene, which is an overdetermined linear system as soon as the graph
//! has cycles — the classic bundle-adjustment-lite step every stitching
//! pipeline runs between matching and compositing (Sarı et al. 2018 §3).
//!
//! The solver here is deterministic and dependency-free:
//!
//! 1. **Connected components** — scenes that never registered against
//!    each other cannot be placed relative to one another; each component
//!    is solved independently, anchored at its smallest scene id.
//! 2. **Spanning-tree initialization** — BFS from the anchor accumulates
//!    translations along tree edges, which is already exact when the
//!    measurements are cycle-consistent.
//! 3. **Gauss-Seidel refinement** — sweeps in ascending scene-id order
//!    re-estimate every non-anchor position as the inlier-weighted mean
//!    of its neighbours' predictions, converging to the weighted
//!    least-squares solution of the translation-difference equations.
//!
//! Because every step iterates scenes/edges in sorted order with f64
//! arithmetic, the solution is bit-identical across runs and node counts
//! — the property the distributed compositing job builds on.
//!
//! Cycle residuals (`(pos_a − pos_b) − t_ab` per edge) are kept as
//! diagnostics: they are ~0 on cycle-consistent inputs and their max/RMS
//! quantify how much the pairwise registrations disagree globally.

use std::collections::BTreeMap;

use crate::coordinator::PairResult;
use crate::util::{DifetError, Result};

/// One measured edge: `pos_a − pos_b = (d_row, d_col)`, weighted (the
/// stitch pipeline uses RANSAC inlier counts as weights).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PairMeasurement {
    pub a: u64,
    pub b: u64,
    pub d_row: f64,
    pub d_col: f64,
    pub weight: f64,
}

/// Convert a registration job's pair results into alignment measurements
/// (unregistered pairs are skipped; their scenes may end up in separate
/// components).
pub fn measurements_from_pairs(pairs: &[PairResult]) -> Vec<PairMeasurement> {
    pairs
        .iter()
        .filter_map(|p| {
            p.translation.map(|t| PairMeasurement {
                a: p.image_a,
                b: p.image_b,
                d_row: t.d_row as f64,
                d_col: t.d_col as f64,
                weight: (t.inliers.max(1)) as f64,
            })
        })
        .collect()
}

/// Residual of one edge under the solved positions:
/// `(pos_a − pos_b) − t_ab`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EdgeResidual {
    pub a: u64,
    pub b: u64,
    pub d_row_err: f64,
    pub d_col_err: f64,
}

impl EdgeResidual {
    /// Euclidean magnitude in pixels.
    pub fn magnitude(&self) -> f64 {
        self.d_row_err.hypot(self.d_col_err)
    }
}

/// Solved global alignment over one scene set.
#[derive(Debug, Clone)]
pub struct GlobalAlignment {
    /// Absolute (row, col) position per scene, anchored per component.
    pub positions: BTreeMap<u64, (f64, f64)>,
    /// Connected components, each sorted ascending; the first id of each
    /// is its anchor (position fixed at (0, 0)).
    pub components: Vec<Vec<u64>>,
    /// Gauss-Seidel sweeps actually run, maximized over components (each
    /// component iterates independently) — always ≥ 1; a forest (or any
    /// cycle-consistent graph) converges on the first sweep, which only
    /// confirms the spanning-tree initialization.
    pub iterations: usize,
    /// Per-edge residuals under the solved positions.
    pub residuals: Vec<EdgeResidual>,
}

impl GlobalAlignment {
    /// Largest edge residual magnitude (0 for edgeless graphs).
    pub fn max_residual(&self) -> f64 {
        self.residuals
            .iter()
            .map(|r| r.magnitude())
            .fold(0.0, f64::max)
    }

    /// Root-mean-square edge residual magnitude.
    pub fn rms_residual(&self) -> f64 {
        if self.residuals.is_empty() {
            return 0.0;
        }
        let sum: f64 = self
            .residuals
            .iter()
            .map(|r| r.d_row_err * r.d_row_err + r.d_col_err * r.d_col_err)
            .sum();
        (sum / self.residuals.len() as f64).sqrt()
    }
}

/// Solver knobs; defaults suit every corpus this repo generates.
#[derive(Debug, Clone, Copy)]
pub struct AlignOptions {
    /// Gauss-Seidel sweep cap.
    pub max_iterations: usize,
    /// Stop when the largest per-sweep position change drops below this.
    pub epsilon: f64,
}

impl Default for AlignOptions {
    fn default() -> Self {
        AlignOptions {
            max_iterations: 256,
            epsilon: 1e-9,
        }
    }
}

/// Solve per-scene absolute positions from pairwise measurements.
///
/// Every scene in `scene_ids` gets a position: scenes without edges are
/// singleton components anchored at (0, 0).  Measurements referencing
/// unknown scenes or self-pairs are rejected.
///
/// Implemented as prepare → per-component solve → assemble, the exact
/// decomposition the distributed align stage runs one component per work
/// unit — the serial baseline and the sharded solve share this code, so
/// they agree bit for bit by construction.
pub fn solve_alignment(
    scene_ids: &[u64],
    measurements: &[PairMeasurement],
    opts: AlignOptions,
) -> Result<GlobalAlignment> {
    let problem = prepare_alignment(scene_ids, measurements, opts)?;
    let solutions: Vec<ComponentSolution> = (0..problem.num_components())
        .map(|c| problem.solve_component(c))
        .collect();
    problem.assemble(&solutions)
}

/// The validated, initialized alignment system: everything up to (but not
/// including) the Gauss-Seidel sweeps.  Components are independent linear
/// systems, so [`AlignProblem::solve_component`] units can run on any
/// node in any order and [`AlignProblem::assemble`] recovers the same
/// [`GlobalAlignment`] the serial solver produces.
#[derive(Debug, Clone)]
pub struct AlignProblem {
    /// Scene ids, sorted ascending (index space for every other field).
    ids: Vec<u64>,
    index: BTreeMap<u64, usize>,
    /// For scene i: (neighbour j, delta with pos_i = pos_j + delta, weight),
    /// sorted by neighbour.
    adj: Vec<Vec<(usize, f64, f64, f64)>>,
    /// Spanning-tree initialization (exact on cycle-consistent inputs).
    pos0: Vec<(f64, f64)>,
    anchor: Vec<bool>,
    /// Connected components, each sorted ascending (scene ids).
    components: Vec<Vec<u64>>,
    measurements: Vec<PairMeasurement>,
    opts: AlignOptions,
}

/// One component's solved positions, parallel to the component's member
/// list (ascending scene id).
#[derive(Debug, Clone)]
pub struct ComponentSolution {
    pub component: usize,
    pub positions: Vec<(f64, f64)>,
    /// Gauss-Seidel sweeps this component ran (always ≥ 1).
    pub iterations: usize,
}

/// Validate the inputs, build the measurement graph, find connected
/// components and run the BFS spanning-tree initialization.
pub fn prepare_alignment(
    scene_ids: &[u64],
    measurements: &[PairMeasurement],
    opts: AlignOptions,
) -> Result<AlignProblem> {
    let mut ids: Vec<u64> = scene_ids.to_vec();
    ids.sort_unstable();
    ids.dedup();
    if ids.len() != scene_ids.len() {
        return Err(DifetError::Job("duplicate scene ids in alignment".into()));
    }
    let index: BTreeMap<u64, usize> = ids.iter().enumerate().map(|(i, &id)| (id, i)).collect();
    for m in measurements {
        if m.a == m.b {
            return Err(DifetError::Job(format!("self-measurement on scene {}", m.a)));
        }
        for id in [m.a, m.b] {
            if !index.contains_key(&id) {
                return Err(DifetError::Job(format!(
                    "measurement ({}, {}) references unknown scene {id}",
                    m.a, m.b
                )));
            }
        }
        if !m.weight.is_finite() || m.weight <= 0.0 || !m.d_row.is_finite() || !m.d_col.is_finite()
        {
            return Err(DifetError::Job(format!(
                "degenerate measurement ({}, {}): weight {}, t ({}, {})",
                m.a, m.b, m.weight, m.d_row, m.d_col
            )));
        }
    }

    // Adjacency: for scene i, (neighbour j, delta such that
    // pos_i = pos_j + delta, weight).  Edge (a, b) with t = pos_a − pos_b
    // gives pos_a = pos_b + t and pos_b = pos_a − t.
    let n = ids.len();
    let mut adj: Vec<Vec<(usize, f64, f64, f64)>> = vec![Vec::new(); n];
    for m in measurements {
        let (ia, ib) = (index[&m.a], index[&m.b]);
        adj[ia].push((ib, m.d_row, m.d_col, m.weight));
        adj[ib].push((ia, -m.d_row, -m.d_col, m.weight));
    }
    // Sorted neighbour order keeps every later loop deterministic.
    for nbrs in &mut adj {
        nbrs.sort_by_key(|e| e.0);
    }

    // ---- connected components + spanning-tree (BFS) initialization ------
    let mut pos: Vec<(f64, f64)> = vec![(0.0, 0.0); n];
    let mut comp_of: Vec<usize> = vec![usize::MAX; n];
    let mut components: Vec<Vec<u64>> = Vec::new();
    for start in 0..n {
        if comp_of[start] != usize::MAX {
            continue;
        }
        let comp_id = components.len();
        let mut members = Vec::new();
        let mut queue = std::collections::VecDeque::from([start]);
        comp_of[start] = comp_id;
        pos[start] = (0.0, 0.0); // anchor: smallest id reaches first
        while let Some(i) = queue.pop_front() {
            members.push(ids[i]);
            for &(j, dr, dc, _) in &adj[i] {
                if comp_of[j] == usize::MAX {
                    comp_of[j] = comp_id;
                    // pos_j = pos_i − delta_ij  (delta is pos_i − pos_j).
                    pos[j] = (pos[i].0 - dr, pos[i].1 - dc);
                    queue.push_back(j);
                }
            }
        }
        members.sort_unstable();
        components.push(members);
    }
    let anchor: Vec<bool> = {
        let mut a = vec![false; n];
        for comp in &components {
            a[index[&comp[0]]] = true;
        }
        a
    };

    Ok(AlignProblem {
        ids,
        index,
        adj,
        pos0: pos,
        anchor,
        components,
        measurements: measurements.to_vec(),
        opts,
    })
}

impl AlignProblem {
    pub fn num_components(&self) -> usize {
        self.components.len()
    }

    /// Connected components, each sorted ascending (scene ids).
    pub fn components(&self) -> &[Vec<u64>] {
        &self.components
    }

    /// Gauss-Seidel refinement over ONE component, starting from the
    /// spanning-tree initialization.  A component's equations only
    /// reference its own members, so sweeping the members in ascending
    /// scene-id order visits exactly the updates the whole-graph sweep
    /// would apply to them — the restriction is bit-exact, and a
    /// per-component stop test terminates each shard independently.
    pub fn solve_component(&self, component: usize) -> ComponentSolution {
        let members: Vec<usize> = self.components[component]
            .iter()
            .map(|id| self.index[id])
            .collect();
        // Scratch positions indexed by the global index space; only this
        // component's entries are read or written.
        let mut pos = self.pos0.clone();
        let mut iterations = 0usize;
        for _ in 0..self.opts.max_iterations {
            let mut max_delta = 0.0f64;
            for &i in &members {
                if self.anchor[i] || self.adj[i].is_empty() {
                    continue;
                }
                let (mut sr, mut sc, mut sw) = (0.0f64, 0.0f64, 0.0f64);
                for &(j, dr, dc, w) in &self.adj[i] {
                    // Neighbour j predicts pos_i = pos_j + delta_ij.
                    sr += w * (pos[j].0 + dr);
                    sc += w * (pos[j].1 + dc);
                    sw += w;
                }
                let next = (sr / sw, sc / sw);
                max_delta = max_delta
                    .max((next.0 - pos[i].0).abs())
                    .max((next.1 - pos[i].1).abs());
                pos[i] = next;
            }
            iterations += 1;
            if max_delta < self.opts.epsilon {
                break;
            }
        }
        ComponentSolution {
            component,
            positions: members.iter().map(|&i| pos[i]).collect(),
            iterations,
        }
    }

    /// Scatter per-component solutions back into the global index space
    /// and compute residuals in measurement input order.  Solutions may
    /// arrive in any order; each component must appear exactly once.
    pub fn assemble(&self, solutions: &[ComponentSolution]) -> Result<GlobalAlignment> {
        if solutions.len() != self.components.len() {
            return Err(DifetError::Job(format!(
                "alignment assemble: {} component solutions for {} components",
                solutions.len(),
                self.components.len()
            )));
        }
        let mut pos = self.pos0.clone();
        let mut seen = vec![false; self.components.len()];
        let mut iterations = 0usize;
        for sol in solutions {
            if sol.component >= self.components.len() || seen[sol.component] {
                return Err(DifetError::Job(format!(
                    "alignment assemble: bad or duplicate component {}",
                    sol.component
                )));
            }
            seen[sol.component] = true;
            let members = &self.components[sol.component];
            if sol.positions.len() != members.len() {
                return Err(DifetError::Job(format!(
                    "alignment assemble: component {} has {} positions for {} members",
                    sol.component,
                    sol.positions.len(),
                    members.len()
                )));
            }
            for (id, &p) in members.iter().zip(&sol.positions) {
                pos[self.index[id]] = p;
            }
            iterations = iterations.max(sol.iterations);
        }

        let residuals: Vec<EdgeResidual> = self
            .measurements
            .iter()
            .map(|m| {
                let (ia, ib) = (self.index[&m.a], self.index[&m.b]);
                EdgeResidual {
                    a: m.a,
                    b: m.b,
                    d_row_err: (pos[ia].0 - pos[ib].0) - m.d_row,
                    d_col_err: (pos[ia].1 - pos[ib].1) - m.d_col,
                }
            })
            .collect();

        Ok(GlobalAlignment {
            positions: self.ids.iter().zip(&pos).map(|(&id, &p)| (id, p)).collect(),
            components: self.components.clone(),
            iterations,
            residuals,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(a: u64, b: u64, dr: f64, dc: f64) -> PairMeasurement {
        PairMeasurement { a, b, d_row: dr, d_col: dc, weight: 1.0 }
    }

    #[test]
    fn chain_is_exact_from_tree_init() {
        // 0—1—2 chain with consistent measurements: pos_a − pos_b = t.
        let al = solve_alignment(
            &[0, 1, 2],
            &[m(0, 1, -10.0, -5.0), m(1, 2, -7.0, 3.0)],
            AlignOptions::default(),
        )
        .unwrap();
        assert_eq!(al.components, vec![vec![0, 1, 2]]);
        assert_eq!(al.positions[&0], (0.0, 0.0));
        let p1 = al.positions[&1];
        let p2 = al.positions[&2];
        assert!((p1.0 - 10.0).abs() < 1e-9 && (p1.1 - 5.0).abs() < 1e-9);
        assert!((p2.0 - 17.0).abs() < 1e-9 && (p2.1 - 2.0).abs() < 1e-9);
        assert!(al.max_residual() < 1e-9);
    }

    #[test]
    fn consistent_cycle_has_zero_residual() {
        // Triangle whose measurements close exactly.
        let al = solve_alignment(
            &[0, 1, 2],
            &[m(0, 1, -4.0, 0.0), m(1, 2, -6.0, -2.0), m(0, 2, -10.0, -2.0)],
            AlignOptions::default(),
        )
        .unwrap();
        assert!(al.max_residual() < 1e-9, "residual {}", al.max_residual());
        let p2 = al.positions[&2];
        assert!((p2.0 - 10.0).abs() < 1e-9 && (p2.1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn inconsistent_cycle_spreads_error_and_reports_residual() {
        // Triangle that fails to close by 3 px on the row axis.
        let al = solve_alignment(
            &[0, 1, 2],
            &[m(0, 1, -4.0, 0.0), m(1, 2, -6.0, 0.0), m(0, 2, -13.0, 0.0)],
            AlignOptions::default(),
        )
        .unwrap();
        // Least squares splits the 3 px misclosure across the three edges.
        assert!(al.max_residual() > 0.5, "residual {}", al.max_residual());
        assert!(al.max_residual() < 3.0, "residual {}", al.max_residual());
        assert!(al.rms_residual() <= al.max_residual());
        // The solved position lands between the two contradictory paths.
        let p2 = al.positions[&2].0;
        assert!(p2 > 10.0 && p2 < 13.0, "pos {p2}");
    }

    #[test]
    fn disconnected_components_are_anchored_independently() {
        let al = solve_alignment(
            &[0, 1, 5, 9],
            &[m(0, 1, -8.0, -8.0), m(5, 9, 2.0, 4.0)],
            AlignOptions::default(),
        )
        .unwrap();
        assert_eq!(al.components, vec![vec![0, 1], vec![5, 9]]);
        assert_eq!(al.positions[&0], (0.0, 0.0));
        assert_eq!(al.positions[&5], (0.0, 0.0));
        let p9 = al.positions[&9];
        assert!((p9.0 + 2.0).abs() < 1e-9 && (p9.1 + 4.0).abs() < 1e-9);
    }

    #[test]
    fn weights_pull_toward_the_heavier_edge() {
        // Two contradictory direct measurements 0→1; the heavier wins.
        let heavy = PairMeasurement { a: 0, b: 1, d_row: -10.0, d_col: 0.0, weight: 9.0 };
        let light = PairMeasurement { a: 0, b: 1, d_row: -20.0, d_col: 0.0, weight: 1.0 };
        let al = solve_alignment(&[0, 1], &[heavy, light], AlignOptions::default()).unwrap();
        let p1 = al.positions[&1].0;
        assert!((p1 - 11.0).abs() < 1e-6, "pos {p1} (weighted mean is 11)");
    }

    #[test]
    fn rejects_bad_inputs() {
        assert!(solve_alignment(&[0, 0], &[], AlignOptions::default()).is_err());
        assert!(solve_alignment(&[0, 1], &[m(0, 0, 1.0, 1.0)], AlignOptions::default()).is_err());
        assert!(solve_alignment(&[0, 1], &[m(0, 7, 1.0, 1.0)], AlignOptions::default()).is_err());
        let mut nan = m(0, 1, f64::NAN, 0.0);
        assert!(solve_alignment(&[0, 1], &[nan], AlignOptions::default()).is_err());
        nan = m(0, 1, 0.0, 0.0);
        nan.weight = 0.0;
        assert!(solve_alignment(&[0, 1], &[nan], AlignOptions::default()).is_err());
    }

    #[test]
    fn sharded_component_solve_matches_serial_bit_for_bit() {
        // Two components, one with an inconsistent cycle (so Gauss-Seidel
        // actually iterates) and one chain; solving the shards in reverse
        // order must reproduce solve_alignment exactly.
        let ids = [0u64, 1, 2, 5, 9];
        let ms = [
            m(0, 1, -4.0, 0.0),
            m(1, 2, -6.0, 0.0),
            m(0, 2, -13.0, 0.0),
            m(5, 9, 2.0, 4.0),
        ];
        let serial = solve_alignment(&ids, &ms, AlignOptions::default()).unwrap();
        let problem = prepare_alignment(&ids, &ms, AlignOptions::default()).unwrap();
        assert_eq!(problem.num_components(), 2);
        let mut sols: Vec<ComponentSolution> = (0..problem.num_components())
            .map(|c| problem.solve_component(c))
            .collect();
        sols.reverse(); // arrival order must not matter
        let sharded = problem.assemble(&sols).unwrap();
        assert_eq!(serial.positions, sharded.positions);
        assert_eq!(serial.components, sharded.components);
        assert_eq!(serial.iterations, sharded.iterations);
        assert_eq!(serial.residuals, sharded.residuals);
        // Assemble rejects missing/duplicate shards.
        assert!(problem.assemble(&sols[..1]).is_err());
        let dup = vec![sols[0].clone(), sols[0].clone()];
        assert!(problem.assemble(&dup).is_err());
    }

    #[test]
    fn measurements_from_pairs_skip_unregistered() {
        use crate::features::matching::Translation;
        let pairs = vec![
            PairResult {
                image_a: 0,
                image_b: 1,
                matches: 40,
                translation: Some(Translation { d_row: 3.0, d_col: -2.0, inliers: 30 }),
            },
            PairResult { image_a: 0, image_b: 2, matches: 2, translation: None },
        ];
        let ms = measurements_from_pairs(&pairs);
        assert_eq!(ms.len(), 1);
        assert_eq!((ms[0].a, ms[0].b), (0, 1));
        assert_eq!((ms[0].d_row, ms[0].d_col, ms[0].weight), (3.0, -2.0, 30.0));
    }
}
