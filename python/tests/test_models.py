"""Behavioural tests for the seven L2 algorithm graphs (model.py).

Each test drives the *same* graph objects that aot.py lowers into the
artifacts, on synthetic tiles with known structure: flat tiles must yield
zero features, corner-rich tiles must light up the corner detectors at the
right locations, and every output must honour the manifest contract
(dtypes, shapes, sentinels, exact counts).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model, ops

TILE = model.TILE


def _rgba(gray01: np.ndarray) -> jnp.ndarray:
    """Promote a [0,1] grayscale image to the RGBA f32 tile layout."""
    g = (gray01 * 255.0).astype(np.float32)
    return jnp.asarray(np.stack([g, g, g, np.full_like(g, 255.0)], axis=-1))


def _checkerboard(n: int = TILE, cell: int = 32) -> np.ndarray:
    idx = np.arange(n) // cell
    return ((idx[:, None] + idx[None, :]) % 2).astype(np.float32)


FULL_CORE = jnp.asarray([0, TILE, 0, TILE], jnp.int32)


@pytest.fixture(scope="module")
def jitted():
    """Jitted graphs with the full-tile core bound (most tests don't care
    about seam attribution; test_core_operand exercises it explicitly)."""
    out = {}
    pat_a, pat_b = jnp.asarray(model.BRIEF_A), jnp.asarray(model.BRIEF_B)
    for name, (b, _) in model.ALGORITHMS.items():
        fn = jax.jit(b())
        if model.takes_pattern(name):
            out[name] = (lambda f: (lambda tile: f(tile, FULL_CORE, pat_a, pat_b)))(fn)
        else:
            out[name] = (lambda f: (lambda tile: f(tile, FULL_CORE)))(fn)
    return out


@pytest.fixture(scope="module")
def checker_out(jitted):
    tile = _rgba(_checkerboard())
    return {name: jax.tree.map(np.asarray, fn(tile)) for name, fn in jitted.items()}


# ---------------------------------------------------------------------------
# Contract: shapes, dtypes, sentinels
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", list(model.ALGORITHMS))
def test_output_contract(name, checker_out):
    out = checker_out[name]
    k = model.TOPK[name]
    count, scores, rows, cols = out[0], out[1], out[2], out[3]
    assert count.dtype == np.int32 and count.shape == ()
    assert scores.shape == (k,) and scores.dtype == np.float32
    assert rows.shape == (k,) and rows.dtype == np.int32
    assert cols.shape == (k,) and cols.dtype == np.int32

    n = min(int(count), k)
    valid_r, valid_c = rows[:n], cols[:n]
    assert np.all((valid_r >= 0) & (valid_r < TILE))
    assert np.all((valid_c >= 0) & (valid_c < TILE))
    assert np.all(rows[n:] == ops.INVALID_COORD)
    assert np.all(np.diff(scores[:n]) <= 1e-5)  # descending

    desc_spec = model.ALGORITHMS[name][1]
    if desc_spec is None:
        assert len(out) == 4
    else:
        dtype, width = desc_spec
        desc = out[4]
        assert desc.shape == (k, width)
        assert desc.dtype == (np.float32 if dtype == "f32" else np.uint32)


@pytest.mark.parametrize("name", list(model.ALGORITHMS))
def test_flat_tile_zero_features(name, jitted):
    """No structure → zero count, all-sentinel coordinates."""
    out = jax.tree.map(np.asarray, jitted[name](_rgba(np.full((TILE, TILE), 0.5))))
    assert int(out[0]) == 0, f"{name} found features in a flat tile"
    assert np.all(out[2] == ops.INVALID_COORD)


# ---------------------------------------------------------------------------
# Detector semantics
# ---------------------------------------------------------------------------


def test_corner_detectors_hit_checkerboard_junctions(checker_out):
    """Checkerboard cell junctions are ideal structure-tensor corners:
    Harris and Shi-Tomasi must place their keypoints on the 32-px lattice.

    (FAST is tested on isolated squares instead — a perfect checkerboard
    junction splits the Bresenham circle 8/8, below the 9-contiguous arc,
    which is FAST's textbook failure case and *should* yield nothing.)
    """
    for name in ("harris", "shi_tomasi"):
        count, _, rows, cols = checker_out[name][:4]
        n = min(int(count), model.TOPK[name])
        assert n > 0, f"{name} found nothing on a checkerboard"
        r_off = np.minimum(rows[:n] % 32, 32 - rows[:n] % 32)
        c_off = np.minimum(cols[:n] % 32, 32 - cols[:n] % 32)
        near = (r_off <= 2) & (c_off <= 2)
        frac = near.mean()
        assert frac > 0.9, f"{name}: only {frac:.0%} of corners on junctions"


def _squares(n: int = TILE, size: int = 32, pitch: int = 64) -> np.ndarray:
    """Bright isolated squares on dark ground; corners at known offsets."""
    img = np.zeros((n, n), np.float32)
    for r0 in range(16, n - size, pitch):
        for c0 in range(16, n - size, pitch):
            img[r0 : r0 + size, c0 : c0 + size] = 1.0
    return img


def test_fast_and_orb_hit_square_corners(jitted):
    """Corners of isolated squares expose a >=12-contiguous arc: FAST (and
    ORB, which seeds from FAST) must fire on — and only near — them."""
    tile = _rgba(_squares())
    corner_offsets = {15, 16, 47, 48}  # square edges at 16 and 48 (mod 64)
    for name in ("fast", "orb"):
        out = jax.tree.map(np.asarray, jitted[name](tile))
        count, rows, cols = int(out[0]), out[2], out[3]
        n = min(count, model.TOPK[name])
        assert n > 0, f"{name} found nothing on isolated squares"
        r_ok = np.isin(rows[:n] % 64, list(corner_offsets)) | (
            np.isin((rows[:n] - 1) % 64, list(corner_offsets))
        ) | np.isin((rows[:n] + 1) % 64, list(corner_offsets))
        c_ok = np.isin(cols[:n] % 64, list(corner_offsets)) | (
            np.isin((cols[:n] - 1) % 64, list(corner_offsets))
        ) | np.isin((cols[:n] + 1) % 64, list(corner_offsets))
        frac = (r_ok & c_ok).mean()
        assert frac > 0.9, f"{name}: only {frac:.0%} on square corners"


def test_fast_rejects_perfect_checkerboard(checker_out):
    """The 8/8 circle split at checkerboard junctions defeats FAST-9 —
    locking in the detector's arc semantics (segment test, not gradient)."""
    assert int(checker_out["fast"][0]) == 0


def test_checkerboard_corner_census(checker_out):
    """~(TILE/32 - 1)^2 interior junctions exist; Harris should find about
    one corner per junction (NMS collapses each to a point)."""
    expected = (TILE // 32 - 1) ** 2  # 225 for 512/32
    count = int(checker_out["harris"][0])
    # A perfectly symmetric junction yields a 2x2 response plateau, and
    # strict NMS admits every plateau member → up to 4 detections/junction.
    assert 0.5 * expected < count <= 4.0 * expected


def test_fast_needs_contrast(jitted):
    """FAST's segment test needs |delta| > t: low-contrast squares
    (delta < t) yield nothing, high-contrast ones plenty."""
    lo = _rgba(0.5 + 0.4 * model.PARAMS["fast_t"] * _squares())
    hi = _rgba(_squares())
    assert int(np.asarray(jitted["fast"](lo)[0])) == 0
    assert int(np.asarray(jitted["fast"](hi)[0])) > 100


def test_sift_finds_blobs_not_edges(jitted):
    """DoG responds to blobs: an isolated Gaussian spot must be detected;
    a pure straight edge must be (mostly) rejected by the edge filter."""
    yy, xx = np.mgrid[0:TILE, 0:TILE].astype(np.float32)
    spot = np.exp(-(((yy - 256) ** 2 + (xx - 256) ** 2) / (2 * 6.0**2)))
    out = jax.tree.map(np.asarray, jitted["sift"](_rgba(spot)))
    count, rows, cols = int(out[0]), out[2], out[3]
    assert count >= 1
    n = min(count, model.TOPK["sift"])
    d = np.sqrt((rows[:n] - 256.0) ** 2 + (cols[:n] - 256.0) ** 2)
    assert d.min() < 6.0, "SIFT keypoint not on the blob centre"

    edge = np.zeros((TILE, TILE), np.float32)
    edge[:, 256:] = 1.0
    out_e = jax.tree.map(np.asarray, jitted["sift"](_rgba(edge)))
    assert int(out_e[0]) <= count * 4  # edge may ring a little, never explode


def test_surf_detects_blob_scale_pair(jitted):
    yy, xx = np.mgrid[0:TILE, 0:TILE].astype(np.float32)
    img = np.zeros((TILE, TILE), np.float32)
    for cy, cx, s in ((128, 128, 3.0), (384, 384, 6.0)):
        img += np.exp(-(((yy - cy) ** 2 + (xx - cx) ** 2) / (2 * s**2)))
    out = jax.tree.map(np.asarray, jitted["surf"](_rgba(img)))
    count, rows, cols = int(out[0]), out[2], out[3]
    assert count >= 2
    n = min(count, model.TOPK["surf"])
    pts = np.stack([rows[:n], cols[:n]], 1).astype(np.float32)
    for cy, cx in ((128, 128), (384, 384)):
        d = np.sqrt(((pts - np.array([cy, cx])) ** 2).sum(1))
        assert d.min() < 4.0, f"SURF missed the blob at ({cy},{cx})"


# ---------------------------------------------------------------------------
# Descriptor semantics
# ---------------------------------------------------------------------------


def test_sift_descriptors_normalized(checker_out):
    count, _, _, _, desc = checker_out["sift"]
    n = min(int(count), model.TOPK["sift"])
    if n == 0:
        pytest.skip("no SIFT keypoints on checkerboard")
    norms = np.linalg.norm(desc[:n], axis=1)
    np.testing.assert_allclose(norms, 1.0, atol=1e-3)
    assert np.all(desc[:n] >= 0.0) and np.all(desc[:n] <= 0.2 + 1e-3)


def test_surf_descriptors_normalized(checker_out):
    count, _, _, _, desc = checker_out["surf"]
    n = min(int(count), model.TOPK["surf"])
    if n == 0:
        pytest.skip("no SURF keypoints on checkerboard")
    np.testing.assert_allclose(np.linalg.norm(desc[:n], axis=1), 1.0, atol=1e-3)


def test_brief_descriptors_deterministic(jitted):
    """Same tile → bit-identical binary descriptors (pure function)."""
    tile = _rgba(_checkerboard(cell=24))
    d1 = np.asarray(jitted["brief"](tile)[4])
    d2 = np.asarray(jitted["brief"](tile)[4])
    np.testing.assert_array_equal(d1, d2)


def test_orb_steering_changes_bits(jitted):
    """Rotating the image must rotate ORB's descriptor frame: descriptors
    of a 90°-rotated tile stay similar to the originals (steering works),
    while *unsteered* BRIEF bits on the rotated tile diverge."""
    rng = np.random.default_rng(5)
    base = rng.uniform(0, 1, size=(TILE, TILE)).astype(np.float32)
    base = np.asarray(
        jnp.asarray(base)
    )  # keep as-is; texture-rich random field
    rot = np.rot90(base).copy()

    out_a = jax.tree.map(np.asarray, jitted["orb"](_rgba(base)))
    out_b = jax.tree.map(np.asarray, jitted["orb"](_rgba(rot)))
    na = min(int(out_a[0]), model.TOPK["orb"])
    nb = min(int(out_b[0]), model.TOPK["orb"])
    assert na > 0 and nb > 0

    # Match keypoints across the rotation: (r, c) -> (TILE-1-c, r) for rot90.
    pts_a = {(int(r), int(c)): i for i, (r, c) in enumerate(zip(out_a[2][:na], out_a[3][:na]))}
    pairs = []
    for j in range(nb):
        rb, cb = int(out_b[2][j]), int(out_b[3][j])
        # inverse map of np.rot90 (counter-clockwise): a_row=cb, a_col=TILE-1-rb
        key = (cb, TILE - 1 - rb)
        if key in pts_a:
            pairs.append((pts_a[key], j))
    if len(pairs) < 10:
        pytest.skip(f"only {len(pairs)} rotation-stable keypoints")

    da, db = out_a[4], out_b[4]

    def hamming(x, y):
        return bin(int(np.bitwise_xor(x, y).astype(np.uint64).sum()))  # unused

    dists = []
    for ia, jb in pairs:
        x = np.bitwise_xor(da[ia], db[jb])
        dists.append(sum(int(v).bit_count() for v in x))
    mean_steered = np.mean(dists)
    # Random 256-bit strings differ in ~128 bits; steered matches must do
    # far better on average.
    assert mean_steered < 100, f"steered ORB hamming {mean_steered:.1f}"


def test_brief_count_sparser_than_fast(jitted):
    """Table 2's ordering: BRIEF's sparse detector finds far fewer points
    than FAST on the same textured tile."""
    rng = np.random.default_rng(9)
    tex = np.clip(
        _squares() * 0.8 + 0.1 + 0.05 * rng.normal(size=(TILE, TILE)), 0, 1
    ).astype(np.float32)
    tile = _rgba(tex)
    n_fast = int(np.asarray(jitted["fast"](tile)[0]))
    n_brief = int(np.asarray(jitted["brief"](tile)[0]))
    assert n_brief * 5 < n_fast


# ---------------------------------------------------------------------------
# Invariance properties
# ---------------------------------------------------------------------------


def test_harris_translation_equivariance(jitted):
    """Shifting the image shifts the keypoints (away from borders)."""
    rng = np.random.default_rng(3)
    img = rng.uniform(0, 1, size=(TILE, TILE)).astype(np.float32)
    shift = 16
    shifted = np.roll(img, (shift, shift), axis=(0, 1))

    out_a = jax.tree.map(np.asarray, jitted["harris"](_rgba(img)))
    out_b = jax.tree.map(np.asarray, jitted["harris"](_rgba(shifted)))
    na = min(int(out_a[0]), model.TOPK["harris"])
    nb = min(int(out_b[0]), model.TOPK["harris"])
    pts_a = set()
    for r, c in zip(out_a[2][:na], out_a[3][:na]):
        if 32 <= r < TILE - 32 and 32 <= c < TILE - 32:
            pts_a.add((int(r) + shift, int(c) + shift))
    hits = sum(
        (int(r), int(c)) in pts_a for r, c in zip(out_b[2][:nb], out_b[3][:nb])
    )
    assert hits > 0.7 * len(pts_a)


def test_counts_scale_with_texture_density(jitted):
    """More junctions → more corners: the census respects density."""
    t_sparse = _rgba(_checkerboard(cell=128))
    t_dense = _rgba(_checkerboard(cell=16))
    for name in ("harris", "shi_tomasi"):
        n_sparse = int(np.asarray(jitted[name](t_sparse)[0]))
        n_dense = int(np.asarray(jitted[name](t_dense)[0]))
        assert n_dense > 4 * max(n_sparse, 1), name


def test_core_operand_restricts_census_and_keypoints():
    """The core rectangle operand must bound both the count and the
    keypoint coordinates — the property the tiler's exactness rests on."""
    rng = np.random.default_rng(2)
    tile = _rgba(rng.uniform(0, 1, size=(TILE, TILE)).astype(np.float32))
    core = jnp.asarray([32, 200, 64, 300], jnp.int32)
    for name in ("harris", "fast", "sift"):
        fn = jax.jit(model.ALGORITHMS[name][0]())
        full = jax.tree.map(np.asarray, fn(tile, FULL_CORE))
        sub = jax.tree.map(np.asarray, fn(tile, core))
        assert int(sub[0]) < int(full[0]), name
        n = min(int(sub[0]), model.TOPK[name])
        rows, cols = sub[2][:n], sub[3][:n]
        valid = rows >= 0
        assert np.all((rows[valid] >= 32) & (rows[valid] < 200)), name
        assert np.all((cols[valid] >= 64) & (cols[valid] < 300)), name


def test_core_censuses_tile_additively():
    """Two disjoint cores' counts must sum to their union's count —
    the exact-partition property Table 2 aggregation relies on."""
    rng = np.random.default_rng(3)
    tile = _rgba(rng.uniform(0, 1, size=(TILE, TILE)).astype(np.float32))
    fn = jax.jit(model.ALGORITHMS["harris"][0]())
    top = jnp.asarray([0, 256, 0, TILE], jnp.int32)
    bottom = jnp.asarray([256, TILE, 0, TILE], jnp.int32)
    n_top = int(np.asarray(fn(tile, top)[0]))
    n_bottom = int(np.asarray(fn(tile, bottom)[0]))
    n_full = int(np.asarray(fn(tile, FULL_CORE)[0]))
    assert n_top + n_bottom == n_full
