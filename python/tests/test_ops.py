"""Unit + property tests for the shared L2 ops (ops.py)."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import ops

settings.register_profile("difet", deadline=None, max_examples=25)
settings.load_profile("difet")


# ---------------------------------------------------------------------------
# grayscale
# ---------------------------------------------------------------------------


def test_grayscale_weights_and_range():
    rgba = np.zeros((4, 4, 4), np.float32)
    rgba[..., 0] = 255.0  # pure red
    g = np.asarray(ops.grayscale(jnp.asarray(rgba)))
    np.testing.assert_allclose(g, 0.299, rtol=1e-6)

    rgba = np.full((4, 4, 4), 255.0, np.float32)
    g = np.asarray(ops.grayscale(jnp.asarray(rgba)))
    np.testing.assert_allclose(g, 1.0, rtol=1e-5)


@given(seed=st.integers(0, 2**31 - 1))
def test_grayscale_ignores_alpha(seed):
    rng = np.random.default_rng(seed)
    rgba = rng.uniform(0, 255, size=(8, 8, 4)).astype(np.float32)
    other = rgba.copy()
    other[..., 3] = rng.uniform(0, 255, size=(8, 8)).astype(np.float32)
    a = np.asarray(ops.grayscale(jnp.asarray(rgba)))
    b = np.asarray(ops.grayscale(jnp.asarray(other)))
    np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# nms_mask
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 2**31 - 1), radius=st.integers(1, 3))
def test_nms_survivors_are_local_maxima(seed, radius):
    rng = np.random.default_rng(seed)
    resp = rng.normal(size=(24, 24)).astype(np.float32)
    mask = np.asarray(ops.nms_mask(jnp.asarray(resp), radius=radius))
    h, w = resp.shape
    for r in range(h):
        for c in range(w):
            if mask[r, c]:
                r0, r1 = max(0, r - radius), min(h, r + radius + 1)
                c0, c1 = max(0, c - radius), min(w, c + radius + 1)
                assert resp[r, c] >= resp[r0:r1, c0:c1].max() - 1e-7


def test_nms_single_peak():
    resp = np.zeros((16, 16), np.float32)
    resp[5, 9] = 1.0
    mask = np.asarray(ops.nms_mask(jnp.asarray(resp)))
    assert mask[5, 9]
    # Only the peak and the flat-zero plateau survive; the peak's ring dies.
    assert not mask[5, 8] and not mask[4, 9] and not mask[6, 10]


# ---------------------------------------------------------------------------
# select_topk
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 2**31 - 1), k=st.sampled_from([4, 16, 64]))
def test_select_topk_contract(seed, k):
    rng = np.random.default_rng(seed)
    resp = rng.normal(size=(16, 16)).astype(np.float32)
    mask = rng.uniform(size=(16, 16)) < 0.15
    count, scores, rows, cols = (
        np.asarray(o)
        for o in ops.select_topk(jnp.asarray(resp), jnp.asarray(mask), k)
    )
    n = int(mask.sum())
    assert count == n  # census is exact, never capped by K
    m = min(n, k)
    # Scores descending over the valid prefix.
    assert np.all(np.diff(scores[:m]) <= 1e-6)
    # Valid prefix points at mask-true pixels with matching scores.
    for i in range(m):
        r, c = int(rows[i]), int(cols[i])
        assert mask[r, c]
        assert abs(scores[i] - resp[r, c]) < 1e-6
    # Sentinels beyond the valid prefix.
    assert np.all(rows[m:] == ops.INVALID_COORD)
    assert np.all(cols[m:] == ops.INVALID_COORD)


def test_select_topk_empty_mask():
    resp = jnp.zeros((8, 8), jnp.float32)
    mask = jnp.zeros((8, 8), bool)
    count, scores, rows, cols = ops.select_topk(resp, mask, 8)
    assert int(count) == 0
    assert np.all(np.asarray(rows) == ops.INVALID_COORD)


# ---------------------------------------------------------------------------
# pack_bits_u32
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 2**31 - 1), words=st.integers(1, 8))
def test_pack_bits_roundtrip(seed, words):
    rng = np.random.default_rng(seed)
    bits = rng.uniform(size=(5, 32 * words)) < 0.5
    packed = np.asarray(ops.pack_bits_u32(jnp.asarray(bits)))
    assert packed.shape == (5, words)
    assert packed.dtype == np.uint32
    # Unpack in numpy and compare (defines the layout Rust mirrors).
    unpacked = np.zeros_like(bits)
    for w in range(words):
        for j in range(32):
            unpacked[:, 32 * w + j] = (packed[:, w] >> j) & 1
    np.testing.assert_array_equal(unpacked.astype(bool), bits)


def test_pack_bits_rejects_ragged():
    import pytest

    with pytest.raises(ValueError):
        ops.pack_bits_u32(jnp.zeros((2, 33), bool))


# ---------------------------------------------------------------------------
# patch sampling
# ---------------------------------------------------------------------------


def test_extract_patches_centering():
    img = np.arange(100, dtype=np.float32).reshape(10, 10)
    pad = 6
    padded = ops.pad_for_patches(jnp.asarray(img), pad)
    rows = jnp.asarray([5], jnp.int32)
    cols = jnp.asarray([7], jnp.int32)
    patch = np.asarray(ops.extract_patches(padded, rows, cols, pad, 3))[0]
    # Centre of the 3x3 patch is the keypoint pixel.
    assert patch[1, 1] == img[5, 7]
    assert patch[0, 0] == img[4, 6]


def test_sample_points_clamps_out_of_bounds():
    img = jnp.asarray(np.ones((8, 8), np.float32))
    pad = 4
    padded = ops.pad_for_patches(img, pad)
    rows = jnp.asarray([ops.INVALID_COORD], jnp.int32)  # sentinel keypoint
    cols = jnp.asarray([ops.INVALID_COORD], jnp.int32)
    dr = jnp.full((1, 3), -100.0)
    dc = jnp.full((1, 3), 100.0)
    vals = np.asarray(ops.sample_points(padded, rows, cols, dr, dc, pad))
    assert np.all(np.isfinite(vals))  # clamped, never OOB


# ---------------------------------------------------------------------------
# resampling
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 2**31 - 1))
def test_down_up_sample_shapes(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(16, 12)).astype(np.float32))
    d = ops.downsample2(x)
    assert d.shape == (8, 6)
    u = ops.upsample2_nn(d)
    assert u.shape == (16, 12)
    # NN upsample replicates each decimated pixel into a 2x2 block.
    un = np.asarray(u)
    dn = np.asarray(d)
    assert np.all(un[0:2, 0:2] == dn[0, 0])
    assert np.all(un[2:4, 4:6] == dn[1, 2])
