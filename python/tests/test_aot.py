"""AOT path tests: lowering produces rust-loadable HLO text + manifest."""

import json
import os
import subprocess
import sys

import pytest

from compile import aot, model


def test_output_spec_matches_algorithms():
    for name in model.ALGORITHMS:
        spec = aot.output_spec(name)
        names = [s["name"] for s in spec]
        assert names[:4] == ["count", "scores", "rows", "cols"]
        desc = model.ALGORITHMS[name][1]
        if desc is None:
            assert len(spec) == 4
        else:
            assert names[4] == "desc"
            assert spec[4]["dims"] == [model.TOPK[name], desc[1]]


def test_lower_harris_hlo_text():
    text = aot.lower_algorithm("harris")
    assert text.startswith("HloModule")
    # Entry layout mentions the input tile and the 4-element result tuple.
    assert "f32[512,512,4]" in text
    assert "s32[4]" in text  # the core-rectangle operand
    assert "s32[2048]" in text
    # HLO text ids must be parseable by xla_extension 0.5.1 (32-bit): the
    # text format carries no explicit ids, which is exactly why we use it.
    assert ".serialize" not in text


def test_lower_rejects_unknown_algorithm():
    with pytest.raises(KeyError):
        aot.lower_algorithm("kaze")


def test_cli_writes_artifacts(tmp_path):
    rc = aot.main(["--out", str(tmp_path), "--algorithms", "fast"])
    assert rc == 0
    assert (tmp_path / "fast.hlo.txt").exists()
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["tile"] == model.TILE
    assert "fast" in manifest["algorithms"]
    entry = manifest["algorithms"]["fast"]
    assert entry["file"] == "fast.hlo.txt"
    assert entry["topk"] == model.TOPK["fast"]
    assert entry["outputs"][0] == {"name": "count", "dtype": "i32", "dims": []}


def test_cli_rejects_unknown(tmp_path):
    with pytest.raises(SystemExit):
        aot.main(["--out", str(tmp_path), "--algorithms", "nope"])


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="run `make artifacts` first",
)
def test_repo_manifest_covers_all_algorithms():
    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    manifest = json.load(open(os.path.join(root, "manifest.json")))
    assert set(manifest["algorithms"]) == set(model.ALGORITHMS)
    for name, entry in manifest["algorithms"].items():
        path = os.path.join(root, entry["file"])
        assert os.path.exists(path), f"missing artifact {path}"
        head = open(path).read(64)
        assert head.startswith("HloModule"), f"{name}: not HLO text"
