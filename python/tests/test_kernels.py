"""L1 correctness gate: Pallas kernels vs the pure-jnp oracles.

hypothesis sweeps shapes/contents; every case asserts allclose between
``kernels.conv`` / ``kernels.harris`` and ``kernels.ref``.  This is the
core correctness signal for the AOT artifacts — the same kernel objects
are embedded in every ``artifacts/<alg>.hlo.txt`` module.
"""

import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import blur2d_pallas, structure_response_pallas
from compile.kernels import ref
from compile.kernels.conv import resolve_block_rows

settings.register_profile("difet", deadline=None, max_examples=25)
settings.load_profile("difet")


def _tile(h, w, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0.0, scale, size=(h, w)).astype(np.float32))


# ---------------------------------------------------------------------------
# gaussian_taps
# ---------------------------------------------------------------------------


@given(
    sigma=st.floats(0.3, 8.0, allow_nan=False),
    radius=st.integers(0, 12),
)
def test_taps_normalized_and_symmetric(sigma, radius):
    taps = ref.gaussian_taps(sigma, radius)
    assert len(taps) == 2 * radius + 1
    assert math.isclose(sum(taps), 1.0, rel_tol=1e-9)
    for i in range(radius):
        assert math.isclose(taps[i], taps[-1 - i], rel_tol=1e-12)
    # Peak at the centre.
    assert taps[radius] == max(taps)


def test_taps_validation():
    with pytest.raises(ValueError):
        ref.gaussian_taps(0.0, 2)
    with pytest.raises(ValueError):
        ref.gaussian_taps(1.0, -1)


# ---------------------------------------------------------------------------
# blur2d: pallas vs ref
# ---------------------------------------------------------------------------


@given(
    h=st.sampled_from([8, 32, 64, 128, 256]),
    w=st.integers(8, 96),
    sigma=st.floats(0.5, 4.0),
    radius=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_blur_matches_ref(h, w, sigma, radius, seed):
    x = _tile(h, w, seed)
    got = blur2d_pallas(x, sigma=sigma, radius=radius)
    want = ref.blur2d_ref(x, sigma, radius)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-6)


def test_blur_production_shape():
    """The exact shape the AOT artifacts use: 512x512, 128-row blocks."""
    x = _tile(512, 512, 7, scale=0.5)
    got = blur2d_pallas(x, sigma=1.6, radius=4, block_rows=128)
    want = ref.blur2d_ref(x, 1.6, 4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-6)


def test_blur_preserves_constants():
    """A constant image is a fixed point of any normalized blur."""
    x = jnp.full((64, 48), 3.25, jnp.float32)
    got = np.asarray(blur2d_pallas(x, sigma=2.0, radius=5))
    np.testing.assert_allclose(got, 3.25, rtol=1e-6)


def test_blur_bad_block_rows_rejected():
    x = _tile(100, 32, 0)
    with pytest.raises(ValueError):
        blur2d_pallas(x, sigma=1.0, radius=2, block_rows=64)


@given(h=st.integers(1, 600))
def test_resolve_block_rows_divides(h):
    b = resolve_block_rows(h, None)
    assert h % b == 0 and 1 <= b <= 128


# ---------------------------------------------------------------------------
# structure response: pallas vs ref
# ---------------------------------------------------------------------------


@given(
    h=st.sampled_from([16, 64, 128, 256]),
    w=st.integers(12, 80),
    mode=st.sampled_from(["harris", "shi_tomasi"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_structure_matches_ref(h, w, mode, seed):
    x = _tile(h, w, seed, scale=0.5)
    got = structure_response_pallas(x, mode=mode)
    want = ref.structure_response_ref(ref.pad_edge(x, ref.STRUCTURE_HALO), mode)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=3e-6, rtol=1e-4
    )


def test_structure_production_shape():
    x = _tile(512, 512, 11, scale=0.5)
    for mode in ("harris", "shi_tomasi"):
        got = structure_response_pallas(x, mode=mode, block_rows=128)
        want = ref.structure_response_ref(
            ref.pad_edge(x, ref.STRUCTURE_HALO), mode
        )
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), atol=3e-6, rtol=1e-4
        )


def test_structure_flat_image_is_zero():
    """No gradients → zero structure tensor → zero response (both modes)."""
    x = jnp.full((64, 64), 0.5, jnp.float32)
    for mode in ("harris", "shi_tomasi"):
        got = np.asarray(structure_response_pallas(x, mode=mode))
        np.testing.assert_allclose(got, 0.0, atol=1e-6)


def test_structure_corner_stronger_than_edge():
    """A step corner must out-score a straight edge under Harris.

    This is Figure 1 of the paper as an executable assertion: corners are
    the features worth detecting; edges score ~0 (one dominant eigenvalue).
    """
    corner = np.zeros((64, 64), np.float32)
    corner[32:, 32:] = 1.0  # L-shaped corner at (32, 32)
    edge = np.zeros((64, 64), np.float32)
    edge[:, 32:] = 1.0  # vertical edge

    rc = np.asarray(structure_response_pallas(jnp.asarray(corner), mode="harris"))
    re = np.asarray(structure_response_pallas(jnp.asarray(edge), mode="harris"))
    assert rc.max() > 10.0 * max(re.max(), 1e-9)


def test_structure_shi_tomasi_le_harris_trace_bound():
    """min-eig ≤ ½·trace always: sanity relation between the two modes."""
    x = _tile(128, 64, 3, scale=0.5)
    st_resp = np.asarray(structure_response_pallas(x, mode="shi_tomasi"))
    # Recompute the trace via the reference pipeline.
    taps = ref.gaussian_taps(1.5, ref.WINDOW_RADIUS)
    xp = ref.pad_edge(x, ref.STRUCTURE_HALO)
    ix, iy = ref.sobel_valid(xp)
    ixx = ref._window_valid(ix * ix, taps)
    iyy = ref._window_valid(iy * iy, taps)
    half_tr = 0.5 * np.asarray(ixx + iyy)
    assert np.all(st_resp <= half_tr + 1e-5)


def test_structure_mode_validation():
    x = _tile(32, 32, 0)
    with pytest.raises(ValueError):
        structure_response_pallas(x, mode="susan")
    with pytest.raises(ValueError):
        ref.structure_response_ref(x, "susan")
