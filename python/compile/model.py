"""L2: per-algorithm JAX compute graphs for DIFET's seven extractors.

Each public ``build_<alg>`` function returns a jittable
``fn(tile: f32[TILE, TILE, 4]) -> tuple`` operating on one RGBA image tile.
``aot.py`` lowers every one of them to an ``artifacts/<alg>.hlo.txt``
module; the Rust coordinator (L3) executes those modules via PJRT on the
request path — Python never runs at extraction time.

The seven algorithms mirror the paper's Section 2 selection:

===========  ==========================  =================================
algorithm    detector                    descriptor
===========  ==========================  =================================
harris       structure tensor (Pallas)   —
shi_tomasi   structure tensor (Pallas)   —
fast         FAST-9 segment test         —
sift         DoG scale-space extrema     128-d gradient histogram (upright)
surf         det-of-Hessian, 2 scales    64-d Haar sums (upright)
brief        structure tensor, sparse    BRIEF-256 binary
orb          FAST-9 + Harris ranking     steered BRIEF-256 (rBRIEF) binary
===========  ==========================  =================================

Upright note: classic SIFT/SURF estimate a dominant orientation and rotate
the descriptor frame.  DIFET's evaluation (Tables 1–2) measures runtime and
feature counts, which orientation does not affect; we implement the upright
variants (as OpenCV's U-SURF does) for SIFT/SURF and full rotation steering
for ORB, whose contribution *is* the rotation (rBRIEF).  DESIGN.md §3
records this substitution.

Output convention (all algorithms)
----------------------------------
``(count i32[], scores f32[K], rows i32[K], cols i32[K][, desc])`` where
``desc`` is f32[K, 128] (SIFT), f32[K, 64] (SURF) or u32[K, 8] (BRIEF/ORB).
``count`` is exact (not capped by K); rows/cols carry -1 sentinels past the
K-th or past ``count``.  The manifest written by ``aot.py`` describes this
layout to the Rust runtime.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from . import ops
from .kernels import blur2d_pallas, structure_response_pallas
from .kernels.ref import gaussian_taps  # noqa: F401  (re-exported for tests)

# ---------------------------------------------------------------------------
# Static configuration.  Changing anything here requires `make artifacts`.
# ---------------------------------------------------------------------------

# Tile edge (pixels).  Scenes (~7000x7000) are tiled by the Rust pipeline.
TILE = 512

# Per-tile top-K caps.  Counts are exact regardless; K only bounds how many
# keypoints get coordinates/descriptors per tile.
TOPK = {
    "harris": 2048,
    "shi_tomasi": 1024,
    "fast": 4096,
    "sift": 2048,
    "surf": 1024,
    "brief": 512,
    "orb": 1024,
}

# Detector thresholds (on [0,1]-normalized grayscale).  Calibrated so the
# synthetic LandSat corpus reproduces Table 2's per-algorithm ordering —
# see EXPERIMENTS.md §Table2-calibration.
PARAMS = {
    "harris_rel_thresh": 0.02,     # OpenCV-style: resp > rel * max(resp)
    "shi_tomasi_rel_thresh": 0.01,
    "fast_t": 0.04,                # FAST brightness delta
    "sift_contrast": 0.012,        # |DoG| threshold
    "sift_edge_r": 10.0,           # Hessian edge-rejection ratio
    "surf_thresh": 6.2e-3,         # ~ hessianThreshold 400 on 8-bit inputs
    "brief_abs_thresh": 2.0e-2,    # absolute min-eig threshold (sparse)
}

# Descriptor geometry.
SIFT_PATCH = 16        # 16x16 patch -> 4x4 cells x 8 bins = 128-d
SURF_PATCH = 20        # 20x20 patch -> 4x4 subregions x 4 stats = 64-d
BRIEF_BITS = 256
BRIEF_PATCH_RADIUS = 15   # pairs drawn within a 31x31 window
PATCH_PAD = 24            # tile padding that keeps every sampler in-bounds
ORB_CENTROID_RADIUS = 7   # intensity-centroid orientation window

# FAST: Bresenham circle of radius 3, 16 points, clockwise from 12 o'clock.
FAST_CIRCLE = (
    (-3, 0), (-3, 1), (-2, 2), (-1, 3), (0, 3), (1, 3), (2, 2), (3, 1),
    (3, 0), (3, -1), (2, -2), (1, -3), (0, -3), (-1, -3), (-2, -2), (-3, -1),
)
FAST_ARC = 9  # FAST-9: need 9 contiguous brighter/darker circle pixels


def _brief_pattern(seed: int = 42) -> tuple[np.ndarray, np.ndarray]:
    """The BRIEF-256 sampling pattern: two (256, 2) f32 offset arrays.

    Gaussian(0, (patch/5)^2) point pairs, the G-II layout from Calonder et
    al. (2010), drawn once from a fixed seed and baked into the HLO as
    constants (and mirrored, bit-for-bit, by ``features::brief`` in Rust).
    """
    rng = np.random.RandomState(seed)
    sigma = (2 * BRIEF_PATCH_RADIUS + 1) / 5.0
    a = rng.normal(0.0, sigma, size=(BRIEF_BITS, 2))
    b = rng.normal(0.0, sigma, size=(BRIEF_BITS, 2))
    lim = float(BRIEF_PATCH_RADIUS)
    return (
        np.clip(a, -lim, lim).astype(np.float32),
        np.clip(b, -lim, lim).astype(np.float32),
    )


BRIEF_A, BRIEF_B = _brief_pattern()


# ---------------------------------------------------------------------------
# Detector primitives
# ---------------------------------------------------------------------------


def fast_maps(gray: jnp.ndarray, t: float) -> tuple[jnp.ndarray, jnp.ndarray]:
    """FAST-9 corner mask and SAD-style score map.

    Vectorized over the whole tile with *bit-packed* ring tests: the 16
    circle indicators become bits 0..15 of an i32 plane; "9 contiguous on
    the circular ring" is the AND of 9 shifted copies of the bit-doubled
    ring.  This replaces the original cumsum formulation (a [24, H, W]
    f32 sliding-window sum) with 8 integer shift-ANDs per polarity —
    ~5× less HLO work, measured in EXPERIMENTS.md §Perf (it is what makes
    FAST the *cheapest* algorithm, as in the paper's Table 1, instead of
    the most expensive).
    Returns ``(corner_mask bool[H,W], score f32[H,W])``.
    """
    h, w = gray.shape
    pad = 3
    gp = jnp.pad(gray, ((pad, pad), (pad, pad)), mode="edge")
    center = gray

    bright_bits = jnp.zeros((h, w), jnp.int32)
    dark_bits = jnp.zeros((h, w), jnp.int32)
    score = jnp.zeros((h, w), jnp.float32)
    for k, (dr, dc) in enumerate(FAST_CIRCLE):
        tap = gp[pad + dr : pad + dr + h, pad + dc : pad + dc + w]
        d = tap - center
        bright_bits = bright_bits | ((d > t).astype(jnp.int32) << k)
        dark_bits = dark_bits | ((d < -t).astype(jnp.int32) << k)
        # Ranking score: total excess contrast around the circle (simpler
        # than OpenCV's exact score; only orders keypoints under NMS).
        score = score + jnp.maximum(jnp.abs(d) - t, 0.0)

    def arc_hit(bits: jnp.ndarray) -> jnp.ndarray:
        ring = bits | (bits << 16)  # circular doubling in one word
        acc = ring
        for i in range(1, FAST_ARC):
            acc = acc & (ring >> i)
        # Bit j of acc ⇔ indicators j..j+8 all set (a 9-arc starting at j).
        return (acc & 0xFFFF) != 0

    corner = arc_hit(bright_bits) | arc_hit(dark_bits)
    return corner, score


def hessian_det_map(gray: jnp.ndarray, sigma: float) -> jnp.ndarray:
    """Scale-normalized determinant-of-Hessian response at scale ``sigma``.

    SURF approximates this with box filters; we compute the Gaussian
    derivatives exactly (blur via the Pallas kernel, then central second
    differences), keeping SURF's 0.9 cross-term correction.
    """
    radius = max(2, int(3.0 * sigma + 0.5))
    g = blur2d_pallas(gray, sigma=sigma, radius=radius)
    gp = jnp.pad(g, ((1, 1), (1, 1)), mode="edge")
    h, w = gray.shape
    c = gp[1 : 1 + h, 1 : 1 + w]
    lxx = gp[1 : 1 + h, 2 : 2 + w] - 2.0 * c + gp[1 : 1 + h, 0:w]
    lyy = gp[2 : 2 + h, 1 : 1 + w] - 2.0 * c + gp[0:h, 1 : 1 + w]
    lxy = 0.25 * (
        gp[2 : 2 + h, 2 : 2 + w]
        - gp[2 : 2 + h, 0:w]
        - gp[0:h, 2 : 2 + w]
        + gp[0:h, 0:w]
    )
    # sigma^4 normalization keeps responses comparable across scales.
    return (sigma ** 4) * (lxx * lyy - (0.9 * lxy) ** 2)


def dog_pyramid(
    gray: jnp.ndarray, base_sigma: float = 1.6, intervals: int = 2
) -> list[jnp.ndarray]:
    """One octave of the SIFT difference-of-Gaussians stack.

    ``intervals + 3`` Gaussian levels -> ``intervals + 2`` DoG planes, each
    full-tile resolution (the caller decimates between octaves).
    """
    ks = 2.0 ** (1.0 / intervals)
    sigmas = [base_sigma * (ks ** i) for i in range(intervals + 3)]
    blurs = [
        blur2d_pallas(gray, sigma=s, radius=max(2, int(3.0 * s + 0.5)))
        for s in sigmas
    ]
    return [blurs[i + 1] - blurs[i] for i in range(len(blurs) - 1)], blurs


def dog_extrema(
    dogs: list[jnp.ndarray], contrast: float, edge_r: float
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Scale-space extrema mask + |DoG| score over the middle DoG layers."""
    stack = jnp.stack(dogs)  # [L, H, W]
    n_layers, h, w = stack.shape
    pad = jnp.pad(stack, ((0, 0), (1, 1), (1, 1)), mode="edge")

    neigh_max = []
    neigh_min = []
    for dl in (-1, 0, 1):
        for dr in (0, 1, 2):
            for dc in (0, 1, 2):
                if dl == 0 and dr == 1 and dc == 1:
                    continue
                sl = pad[:, dr : dr + h, dc : dc + w]
                sl = jnp.roll(sl, -dl, axis=0)
                neigh_max.append(sl)
                neigh_min.append(sl)
    nmax = jnp.max(jnp.stack(neigh_max), axis=0)
    nmin = jnp.min(jnp.stack(neigh_min), axis=0)

    is_max = stack > nmax
    is_min = stack < nmin
    extremum = (is_max | is_min) & (jnp.abs(stack) > contrast)

    # Edge rejection: 2x2 Hessian of each DoG plane, tr^2/det < (r+1)^2/r.
    pd = jnp.pad(stack, ((0, 0), (1, 1), (1, 1)), mode="edge")
    c = pd[:, 1 : 1 + h, 1 : 1 + w]
    dxx = pd[:, 1 : 1 + h, 2 : 2 + w] - 2 * c + pd[:, 1 : 1 + h, 0:w]
    dyy = pd[:, 2 : 2 + h, 1 : 1 + w] - 2 * c + pd[:, 0:h, 1 : 1 + w]
    dxy = 0.25 * (
        pd[:, 2 : 2 + h, 2 : 2 + w]
        - pd[:, 2 : 2 + h, 0:w]
        - pd[:, 0:h, 2 : 2 + w]
        + pd[:, 0:h, 0:w]
    )
    tr = dxx + dyy
    det = dxx * dyy - dxy * dxy
    edge_ok = (det > 0) & (tr * tr * edge_r < (edge_r + 1.0) ** 2 * det)

    # Only interior layers are true 3-D extrema; zero the boundary layers.
    layer_ok = jnp.zeros((n_layers, 1, 1), bool).at[1:-1].set(True)
    mask3 = extremum & edge_ok & layer_ok
    score3 = jnp.where(mask3, jnp.abs(stack), 0.0)

    mask = jnp.any(mask3, axis=0)
    score = jnp.max(score3, axis=0)
    return mask, score


# ---------------------------------------------------------------------------
# Descriptor primitives
# ---------------------------------------------------------------------------


def sift_descriptors(
    blurred: jnp.ndarray, rows: jnp.ndarray, cols: jnp.ndarray
) -> jnp.ndarray:
    """Upright 128-d SIFT descriptors at the given keypoints.

    16x16 patch of the σ≈1.6-blurred image → per-pixel gradient magnitude /
    orientation → Gaussian-weighted soft-binned 4x4x8 histogram → L2
    normalize, 0.2-clip, renormalize (Lowe 2004 §6).
    """
    padded = ops.pad_for_patches(blurred, PATCH_PAD)
    patches = ops.extract_patches(padded, rows, cols, PATCH_PAD, SIFT_PATCH + 2)
    # Central-difference gradients on the 18x18 patch -> 16x16 interior.
    gy = 0.5 * (patches[:, 2:, 1:-1] - patches[:, :-2, 1:-1])
    gx = 0.5 * (patches[:, 1:-1, 2:] - patches[:, 1:-1, :-2])
    mag = jnp.sqrt(gx * gx + gy * gy)
    ang = jnp.arctan2(gy, gx)  # [-pi, pi]

    # Gaussian window over the patch.
    idx = jnp.arange(SIFT_PATCH, dtype=jnp.float32) - (SIFT_PATCH - 1) / 2.0
    wr = jnp.exp(-(idx ** 2) / (2.0 * (SIFT_PATCH / 2.0) ** 2))
    window = wr[:, None] * wr[None, :]
    wmag = mag * window[None, :, :]

    # Soft orientation binning into 8 bins.
    nbins = 8
    binf = (ang + jnp.pi) * (nbins / (2.0 * jnp.pi))
    b0 = jnp.floor(binf)
    frac = binf - b0
    b0 = b0.astype(jnp.int32) % nbins
    b1 = (b0 + 1) % nbins

    onehot0 = jax.nn.one_hot(b0, nbins, dtype=wmag.dtype) * (1.0 - frac)[..., None]
    onehot1 = jax.nn.one_hot(b1, nbins, dtype=wmag.dtype) * frac[..., None]
    votes = (onehot0 + onehot1) * wmag[..., None]  # [K, 16, 16, 8]

    k = votes.shape[0]
    cells = votes.reshape(k, 4, 4, 4, 4, nbins).sum(axis=(2, 4))  # [K,4,4,8]
    desc = cells.reshape(k, 128)

    norm = jnp.linalg.norm(desc, axis=1, keepdims=True) + 1e-7
    desc = jnp.clip(desc / norm, 0.0, 0.2)
    norm = jnp.linalg.norm(desc, axis=1, keepdims=True) + 1e-7
    return (desc / norm).astype(jnp.float32)


def surf_descriptors(
    gray: jnp.ndarray, rows: jnp.ndarray, cols: jnp.ndarray
) -> jnp.ndarray:
    """Upright 64-d SURF descriptors (Bay et al. 2008, U-SURF variant).

    20x20 patch of the σ=1-smoothed image; Haar responses dx, dy per pixel;
    4x4 subregions each contributing (Σdx, Σdy, Σ|dx|, Σ|dy|).
    """
    smooth = blur2d_pallas(gray, sigma=1.0, radius=3)
    padded = ops.pad_for_patches(smooth, PATCH_PAD)
    patches = ops.extract_patches(padded, rows, cols, PATCH_PAD, SURF_PATCH + 2)
    dy = 0.5 * (patches[:, 2:, 1:-1] - patches[:, :-2, 1:-1])
    dx = 0.5 * (patches[:, 1:-1, 2:] - patches[:, 1:-1, :-2])

    k = dx.shape[0]
    sub = SURF_PATCH // 4

    def stats(v: jnp.ndarray) -> jnp.ndarray:
        blocks = v.reshape(k, 4, sub, 4, sub)
        return blocks.sum(axis=(2, 4))  # [K, 4, 4]

    feats = jnp.stack(
        [stats(dx), stats(dy), stats(jnp.abs(dx)), stats(jnp.abs(dy))], axis=-1
    )  # [K, 4, 4, 4]
    desc = feats.reshape(k, 64)
    norm = jnp.linalg.norm(desc, axis=1, keepdims=True) + 1e-7
    return (desc / norm).astype(jnp.float32)


def brief_descriptors(
    gray: jnp.ndarray,
    rows: jnp.ndarray,
    cols: jnp.ndarray,
    pat_a: jnp.ndarray,
    pat_b: jnp.ndarray,
    angles: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """BRIEF-256 binary descriptors, optionally steered by ``angles`` (ORB).

    Intensity pairs are compared on a σ=2 smoothed image (Calonder et al.
    recommend pre-smoothing for noise robustness).  With ``angles`` given,
    the pattern is rotated per-keypoint — Rublee et al.'s rBRIEF steering.
    Returns u32[K, 8] packed little-endian within each word.

    The sampling pattern arrives as *runtime operands* (``pat_a/pat_b``,
    f32[256,2]) rather than baked constants: xla_extension 0.5.1 (the Rust
    runtime's XLA) corrupts large constant literals on the HLO-text
    round-trip, silently zeroing every descriptor.  The Rust engine feeds
    the generated `features::brief_pattern` constants — bit-identical to
    ``BRIEF_A``/``BRIEF_B`` — with every call (DESIGN.md §7).
    """
    smooth = blur2d_pallas(gray, sigma=2.0, radius=5)
    padded = ops.pad_for_patches(smooth, PATCH_PAD)

    a = pat_a  # [256, 2] (dr, dc)
    b = pat_b
    k = rows.shape[0]
    if angles is None:
        a_dr = jnp.broadcast_to(a[:, 0], (k, BRIEF_BITS))
        a_dc = jnp.broadcast_to(a[:, 1], (k, BRIEF_BITS))
        b_dr = jnp.broadcast_to(b[:, 0], (k, BRIEF_BITS))
        b_dc = jnp.broadcast_to(b[:, 1], (k, BRIEF_BITS))
    else:
        cos = jnp.cos(angles)[:, None]
        sin = jnp.sin(angles)[:, None]
        a_dr = a[None, :, 0] * cos + a[None, :, 1] * sin
        a_dc = -a[None, :, 0] * sin + a[None, :, 1] * cos
        b_dr = b[None, :, 0] * cos + b[None, :, 1] * sin
        b_dc = -b[None, :, 0] * sin + b[None, :, 1] * cos

    va = ops.sample_points(padded, rows, cols, a_dr, a_dc, PATCH_PAD)
    vb = ops.sample_points(padded, rows, cols, b_dr, b_dc, PATCH_PAD)
    return ops.pack_bits_u32(va < vb)


def orb_orientations(
    gray: jnp.ndarray, rows: jnp.ndarray, cols: jnp.ndarray
) -> jnp.ndarray:
    """Intensity-centroid keypoint orientation (Rosin moments, ORB §3.2)."""
    padded = ops.pad_for_patches(gray, PATCH_PAD)
    size = 2 * ORB_CENTROID_RADIUS + 1
    patches = ops.extract_patches(padded, rows, cols, PATCH_PAD, size)
    coords = jnp.arange(size, dtype=jnp.float32) - ORB_CENTROID_RADIUS
    rr = coords[:, None]
    cc = coords[None, :]
    disk = (rr * rr + cc * cc) <= ORB_CENTROID_RADIUS ** 2
    w = patches * disk[None, :, :]
    m01 = jnp.sum(w * rr[None, :, :], axis=(1, 2))
    m10 = jnp.sum(w * cc[None, :, :], axis=(1, 2))
    return jnp.arctan2(m01, m10)


# ---------------------------------------------------------------------------
# Algorithm graphs
# ---------------------------------------------------------------------------


def _structure_detector(mode: str, rel_thresh_key: str, k: int):
    def fn(tile: jnp.ndarray, core: jnp.ndarray):
        gray = ops.grayscale(tile)
        resp = structure_response_pallas(gray, mode=mode)
        thresh = PARAMS[rel_thresh_key] * jnp.max(resp)
        mask = (
            ops.nms_mask(resp)
            & (resp > jnp.maximum(thresh, 1e-12))
            & ops.core_mask(resp.shape, core)
        )
        return ops.select_topk(resp, mask, k)

    return fn


def build_harris():
    """Harris corner detection (paper's first mapper pseudo-code)."""
    return _structure_detector("harris", "harris_rel_thresh", TOPK["harris"])


def build_shi_tomasi():
    """Shi-Tomasi (min-eigenvalue) corners.

    The per-image 400-corner cap implied by Table 2 (counts are exactly
    400·N) is OpenCV ``goodFeaturesToTrack``'s ``maxCorners``; DIFET applies
    it where the paper does — at per-image aggregation, in the Rust
    coordinator — so the tile graph reports uncapped counts.
    """
    return _structure_detector(
        "shi_tomasi", "shi_tomasi_rel_thresh", TOPK["shi_tomasi"]
    )


def build_fast():
    """FAST-9 segment-test corners."""

    def fn(tile: jnp.ndarray, core: jnp.ndarray):
        gray = ops.grayscale(tile)
        corner, score = fast_maps(gray, PARAMS["fast_t"])
        mask = corner & ops.nms_mask(score) & ops.core_mask(score.shape, core)
        return ops.select_topk(score, mask, TOPK["fast"])

    return fn


def build_sift():
    """SIFT: 2-octave DoG detector + upright 128-d descriptors."""

    def fn(tile: jnp.ndarray, core: jnp.ndarray):
        gray = ops.grayscale(tile)

        dogs0, blurs0 = dog_pyramid(gray)
        mask0, score0 = dog_extrema(
            dogs0, PARAMS["sift_contrast"], PARAMS["sift_edge_r"]
        )
        mask0 = mask0 & ops.core_mask(mask0.shape, core)

        g1 = ops.downsample2(blurs0[2])  # ~2x base sigma, the octave seed
        dogs1, _ = dog_pyramid(g1)
        mask1, score1 = dog_extrema(
            dogs1, PARAMS["sift_contrast"], PARAMS["sift_edge_r"]
        )
        # Octave-1 core at half resolution: [r0/2, ceil(r1/2)) etc. —
        # mirrors the Rust baseline exactly (sift.rs::extract).
        core1 = jnp.stack(
            [core[0] // 2, -(-core[1] // 2), core[2] // 2, -(-core[3] // 2)]
        )
        mask1 = mask1 & ops.core_mask(mask1.shape, core1)

        # Exact census: octave counts are independent detections.
        count = jnp.sum(mask0, dtype=jnp.int32) + jnp.sum(mask1, dtype=jnp.int32)

        # Keypoints: merge octave-1 onto the tile grid (NN upsample) and
        # keep the stronger response where both octaves fire.
        score1_up = ops.upsample2_nn(score1)
        mask1_up = ops.upsample2_nn(mask1)
        score = jnp.maximum(score0, score1_up)
        mask = mask0 | mask1_up
        _, scores, rows, cols = ops.select_topk(score, mask, TOPK["sift"])

        desc = sift_descriptors(blurs0[1], rows, cols)
        return count, scores, rows, cols, desc

    return fn


def build_surf():
    """SURF: det-of-Hessian blobs at two scales + upright 64-d descriptors."""

    def fn(tile: jnp.ndarray, core: jnp.ndarray):
        gray = ops.grayscale(tile)
        d1 = hessian_det_map(gray, 1.2)
        d2 = hessian_det_map(gray, 2.4)
        resp = jnp.maximum(d1, d2)
        mask = (
            ops.nms_mask(resp)
            & (resp > PARAMS["surf_thresh"])
            & ops.core_mask(resp.shape, core)
        )
        count, scores, rows, cols = ops.select_topk(resp, mask, TOPK["surf"])
        desc = surf_descriptors(gray, rows, cols)
        return count, scores, rows, cols, desc

    return fn


def build_brief():
    """BRIEF-256 on a sparse min-eigenvalue detector.

    The paper pairs BRIEF with a sparse detector (its Table 2 count is
    ~1.2k/image, 200x sparser than FAST); we use the Shi-Tomasi response
    with an *absolute* quality threshold, which reproduces that density.
    """

    def fn(tile: jnp.ndarray, core: jnp.ndarray, pat_a: jnp.ndarray, pat_b: jnp.ndarray):
        gray = ops.grayscale(tile)
        resp = structure_response_pallas(gray, mode="shi_tomasi")
        mask = (
            ops.nms_mask(resp)
            & (resp > PARAMS["brief_abs_thresh"])
            & ops.core_mask(resp.shape, core)
        )
        count, scores, rows, cols = ops.select_topk(resp, mask, TOPK["brief"])
        desc = brief_descriptors(gray, rows, cols, pat_a, pat_b)
        return count, scores, rows, cols, desc

    return fn


def build_orb():
    """ORB: FAST-9 keypoints, Harris-ranked, steered BRIEF-256 descriptors.

    The per-image 500-feature cap (Table 2 counts are exactly 500·N —
    OpenCV's ``nfeatures`` default) is applied at per-image aggregation in
    the Rust coordinator, ranking tiles' keypoints by this Harris score.
    """

    def fn(tile: jnp.ndarray, core: jnp.ndarray, pat_a: jnp.ndarray, pat_b: jnp.ndarray):
        gray = ops.grayscale(tile)
        corner, _ = fast_maps(gray, PARAMS["fast_t"])
        harris = structure_response_pallas(gray, mode="harris")
        score = jnp.where(corner, harris, ops.NEG_LARGE)
        mask = corner & ops.nms_mask(score) & ops.core_mask(score.shape, core)
        count, scores, rows, cols = ops.select_topk(score, mask, TOPK["orb"])
        angles = orb_orientations(gray, rows, cols)
        desc = brief_descriptors(gray, rows, cols, pat_a, pat_b, angles=angles)
        return count, scores, rows, cols, desc

    return fn


def takes_pattern(name: str) -> bool:
    """Does this algorithm's executable take the two pattern operands?"""
    return name in ("brief", "orb")


# Registry consumed by aot.py and the tests.  Order matches the paper's
# Table 1 rows.
ALGORITHMS = {
    "harris": (build_harris, None),
    "shi_tomasi": (build_shi_tomasi, None),
    "sift": (build_sift, ("f32", 128)),
    "surf": (build_surf, ("f32", 64)),
    "fast": (build_fast, None),
    "brief": (build_brief, ("u32", 8)),
    "orb": (build_orb, ("u32", 8)),
}
