"""AOT compiler: lower every DIFET algorithm graph to HLO text artifacts.

This is the *only* place Python meets the Rust runtime, and it runs at
build time only (``make artifacts``).  For each algorithm in
``model.ALGORITHMS`` it:

1. builds the L2 graph (which embeds the L1 Pallas kernels),
2. lowers ``jax.jit(fn)`` for a ``f32[TILE, TILE, 4]`` example tile,
3. converts the StableHLO module to an XlaComputation and dumps **HLO
   text** to ``artifacts/<alg>.hlo.txt``,
4. records the executable's I/O contract in ``artifacts/manifest.json``
   for the Rust runtime to parse.

HLO *text* (never ``HloModuleProto.serialize()``) is the interchange
format: jax >= 0.5 emits protos with 64-bit instruction ids which the
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def output_spec(name: str) -> list[dict]:
    """The output-tuple contract for one algorithm (mirrored in Rust)."""
    k = model.TOPK[name]
    spec = [
        {"name": "count", "dtype": "i32", "dims": []},
        {"name": "scores", "dtype": "f32", "dims": [k]},
        {"name": "rows", "dtype": "i32", "dims": [k]},
        {"name": "cols", "dtype": "i32", "dims": [k]},
    ]
    desc = model.ALGORITHMS[name][1]
    if desc is not None:
        dtype, width = desc
        spec.append({"name": "desc", "dtype": dtype, "dims": [k, width]})
    return spec


def lower_algorithm(name: str) -> str:
    builder, _ = model.ALGORITHMS[name]
    fn = builder()
    tile = jax.ShapeDtypeStruct((model.TILE, model.TILE, 4), jax.numpy.float32)
    core = jax.ShapeDtypeStruct((4,), jax.numpy.int32)
    args = [tile, core]
    if model.takes_pattern(name):
        # BRIEF-256 pattern as runtime operands (see brief_descriptors).
        pat = jax.ShapeDtypeStruct((model.BRIEF_BITS, 2), jax.numpy.float32)
        args += [pat, pat]
    return to_hlo_text(jax.jit(fn).lower(*args))


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument(
        "--algorithms",
        default="all",
        help="comma-separated subset (default: all seven)",
    )
    args = ap.parse_args(argv)

    names = (
        list(model.ALGORITHMS)
        if args.algorithms == "all"
        else [a.strip() for a in args.algorithms.split(",") if a.strip()]
    )
    unknown = [n for n in names if n not in model.ALGORITHMS]
    if unknown:
        ap.error(f"unknown algorithms: {unknown}; known: {list(model.ALGORITHMS)}")

    os.makedirs(args.out, exist_ok=True)
    manifest: dict = {
        "manifest_version": 1,
        "tile": model.TILE,
        "params": dict(model.PARAMS),
        "algorithms": {},
    }

    for name in names:
        t0 = time.time()
        text = lower_algorithm(name)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out, fname)
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest["algorithms"][name] = {
            "file": fname,
            "topk": model.TOPK[name],
            "outputs": output_spec(name),
            "takes_pattern": model.takes_pattern(name),
            "sha256_16": digest,
            "hlo_bytes": len(text),
        }
        print(
            f"[aot] {name:11s} -> {fname:22s} "
            f"{len(text) / 1e6:6.2f} MB  {time.time() - t0:5.1f}s",
            file=sys.stderr,
        )

    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"[aot] wrote {mpath} ({len(names)} algorithms)", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
