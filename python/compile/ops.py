"""Shared L2 graph ops: grayscale, NMS, top-K selection, patch sampling.

These are the static-shape building blocks that turn a dense response map
into the fixed-size keypoint tensors the Rust coordinator consumes.  All
shapes are compile-time constants — XLA/PJRT executables are AOT-compiled
once per algorithm and reused for every tile of every scene, so nothing
here may depend on data-dependent sizes.  Data-dependent *results* (how
many features exist) travel as an explicit ``count`` scalar plus validity
sentinels (row = col = -1) in the fixed-size arrays.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

# Sentinel filled into the row/col slots of invalid (beyond-count) keypoints.
INVALID_COORD = -1
# Effectively -inf for masked response values; finite so top_k stays stable.
NEG_LARGE = -1.0e30


def grayscale(rgba: jnp.ndarray) -> jnp.ndarray:
    """ITU-R BT.601 luma from an ``f32[H, W, 4]`` RGBA tile in [0, 255].

    Matches step 2 of the paper's mapper pseudo-code ("convert image to
    grayscale").  Output is normalized to [0, 1] so every detector threshold
    below is resolution-of-quantization independent.
    """
    r, g, b = rgba[..., 0], rgba[..., 1], rgba[..., 2]
    return (0.299 * r + 0.587 * g + 0.114 * b) * (1.0 / 255.0)


def core_mask(shape: tuple[int, int], core: jnp.ndarray) -> jnp.ndarray:
    """Ownership mask from a ``core = [r0, r1, c0, c1]`` i32[4] operand.

    Tiles overlap (see ``rust/src/imagery/tiler.rs``); every detection is
    attributed to exactly one tile — the one whose core rectangle contains
    it.  The rectangle is a *runtime operand* so one AOT executable serves
    every tile position (interior, border, corner).
    """
    h, w = shape
    rows = jnp.arange(h, dtype=jnp.int32)
    cols = jnp.arange(w, dtype=jnp.int32)
    row_ok = (rows >= core[0]) & (rows < core[1])
    col_ok = (cols >= core[2]) & (cols < core[3])
    return row_ok[:, None] & col_ok[None, :]


def nms_mask(resp: jnp.ndarray, radius: int = 1) -> jnp.ndarray:
    """Strict 2-D non-maximum suppression mask.

    A pixel survives iff it equals the max over its ``(2r+1)^2`` window.
    Plateau ties admit every plateau member — measurably rare on float
    responses and identical to OpenCV's dilate-compare idiom.
    """
    size = 2 * radius + 1
    pooled = lax.reduce_window(
        resp,
        -jnp.inf,
        lax.max,
        window_dimensions=(size, size),
        window_strides=(1, 1),
        padding="SAME",
    )
    return resp >= pooled


def select_topk(
    resp: jnp.ndarray, mask: jnp.ndarray, k: int
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Masked top-K keypoint selection over a dense response map.

    Returns ``(count, scores, rows, cols)``:
      count  — i32 scalar, the exact number of mask-true pixels (NOT capped
               at K; Table 2 is computed from this, so the cap never skews
               the census),
      scores — f32[K] descending, NEG_LARGE beyond ``count``,
      rows/cols — i32[K], INVALID_COORD beyond ``count``.
    """
    h, w = resp.shape
    count = jnp.sum(mask, dtype=jnp.int32)
    flat = jnp.where(mask, resp, NEG_LARGE).reshape(-1)
    # NOTE: deliberately NOT lax.top_k — jax lowers it to the `topk(...,
    # largest=true)` HLO instruction, which the xla_extension 0.5.1 text
    # parser (the Rust runtime's XLA) rejects.  A descending variadic sort
    # lowers to the classic `sort` op and round-trips cleanly; the flat
    # index as sort value keeps ties in stable flat order, matching the
    # Rust baseline's deterministic tie-break.
    idx_all = jnp.arange(flat.shape[0], dtype=jnp.int32)
    sorted_scores, sorted_idx = lax.sort((-flat, idx_all), num_keys=1)
    scores = -sorted_scores[:k]
    idx = sorted_idx[:k]
    valid = scores > NEG_LARGE * 0.5
    rows = jnp.where(valid, (idx // w).astype(jnp.int32), INVALID_COORD)
    cols = jnp.where(valid, (idx % w).astype(jnp.int32), INVALID_COORD)
    return count, scores.astype(jnp.float32), rows, cols


def pad_for_patches(gray: jnp.ndarray, pad: int) -> jnp.ndarray:
    """Edge-replicate pad so patch sampling near borders stays in-bounds."""
    return jnp.pad(gray, ((pad, pad), (pad, pad)), mode="edge")


def sample_points(
    padded: jnp.ndarray,
    rows: jnp.ndarray,
    cols: jnp.ndarray,
    dr: jnp.ndarray,
    dc: jnp.ndarray,
    pad: int,
) -> jnp.ndarray:
    """Nearest-neighbour sample ``padded`` at per-keypoint offset points.

    ``rows/cols`` are i32[K] tile coordinates (possibly INVALID_COORD —
    clamping keeps those reads in-bounds and the results are discarded via
    the validity mask downstream).  ``dr/dc`` are f32[K, P] per-keypoint
    offsets (already rotated, if the caller steers the pattern).  Returns
    f32[K, P].
    """
    hp, wp = padded.shape
    y = jnp.clip(
        jnp.round(rows[:, None].astype(jnp.float32) + pad + dr).astype(jnp.int32),
        0,
        hp - 1,
    )
    x = jnp.clip(
        jnp.round(cols[:, None].astype(jnp.float32) + pad + dc).astype(jnp.int32),
        0,
        wp - 1,
    )
    return padded[y, x]


def extract_patches(
    padded: jnp.ndarray, rows: jnp.ndarray, cols: jnp.ndarray, pad: int, size: int
) -> jnp.ndarray:
    """Gather an axis-aligned ``size``×``size`` patch around each keypoint.

    The patch is centred: its top-left corner sits at ``(row - size//2,
    col - size//2)`` in tile coordinates.  Returns f32[K, size, size].
    """
    half = size // 2

    def one(r: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
        r0 = jnp.clip(r + pad - half, 0, padded.shape[0] - size)
        c0 = jnp.clip(c + pad - half, 0, padded.shape[1] - size)
        return lax.dynamic_slice(padded, (r0, c0), (size, size))

    return jax.vmap(one)(rows, cols)


def pack_bits_u32(bits: jnp.ndarray) -> jnp.ndarray:
    """Pack a boolean ``[K, 32*W]`` matrix into ``u32[K, W]`` words.

    Bit ``j`` of word ``w`` is comparison ``32*w + j`` — the layout the Rust
    ``features::descriptor`` module mirrors for Hamming matching.
    """
    k, n = bits.shape
    if n % 32 != 0:
        raise ValueError(f"bit count {n} not a multiple of 32")
    words = bits.reshape(k, n // 32, 32).astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(words << shifts[None, None, :], axis=-1, dtype=jnp.uint32)


def downsample2(x: jnp.ndarray) -> jnp.ndarray:
    """2× decimation (every other pixel) — SIFT octave step."""
    return x[::2, ::2]


def upsample2_nn(x: jnp.ndarray) -> jnp.ndarray:
    """2× nearest-neighbour upsample — maps octave-1 maps back to tile res."""
    return jnp.repeat(jnp.repeat(x, 2, axis=0), 2, axis=1)
