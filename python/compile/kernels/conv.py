"""L1 Pallas kernel: separable Gaussian blur over row-blocked tiles.

This is the single hottest primitive in DIFET's per-mapper pipeline: every
one of the seven extractors begins with one or more Gaussian smoothing
passes (Harris/Shi-Tomasi window, SIFT scale space, SURF derivative scale,
BRIEF pattern smoothing).  The paper runs it inside OpenCV per mapper; here
it is a Pallas kernel that lowers into the same HLO module as the L2 graph.

TPU mapping (§Hardware-Adaptation in DESIGN.md)
-----------------------------------------------
* Grid: 1-D over row blocks of the output.  Each program instance produces
  a ``(BLOCK_ROWS, W)`` slab — on real hardware each slab (plus its halo)
  is staged HBM→VMEM once and both separable passes run out of VMEM, so
  every input element crosses the HBM boundary exactly once.
* VMEM budget: input slab ``(BLOCK_ROWS + 2*radius, W + 2*radius)`` f32 plus
  one intermediate of the same height — at BLOCK_ROWS=128, W=512, radius≤8
  that is < 1.2 MiB, comfortably inside a 16 MiB VMEM with double-buffering
  headroom (see EXPERIMENTS.md §Perf for the footprint table).
* The taps are compile-time constants; the two passes are fully unrolled
  multiply-adds, i.e. pure VPU work with unit-stride lane access.

The kernel consumes an **edge-pre-padded** input (``pad_edge``) so the
program body is branch-free; the L2 graph pads once and reuses the padded
tile for every primitive that needs a halo.

CPU note: ``interpret=True`` is mandatory in this environment — real TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import gaussian_taps, pad_edge

# Output rows produced per grid step.  512-row tiles → 4 programs.
BLOCK_ROWS = 128


def _blur_block_kernel(xp_ref, o_ref, *, taps: tuple[float, ...], block_rows: int):
    """One grid step: separable blur for ``block_rows`` output rows.

    ``xp_ref`` holds the full padded tile ``(H + 2r, W + 2r)``; the program
    loads its slab (output rows ``i*block_rows ..`` plus the halo), runs the
    vertical then horizontal pass as unrolled static-slice multiply-adds,
    and stores the valid ``(block_rows, W)`` result.
    """
    i = pl.program_id(0)
    radius = (len(taps) - 1) // 2
    w_pad = xp_ref.shape[1]
    w_out = w_pad - 2 * radius

    # Load slab: block_rows + 2*radius rows, all padded columns.
    slab = pl.load(
        xp_ref, (pl.dslice(i * block_rows, block_rows + 2 * radius), slice(None))
    )

    # Vertical pass (consumes the row halo).
    vert = jnp.zeros((block_rows, w_pad), slab.dtype)
    for k, t in enumerate(taps):
        vert = vert + t * slab[k : k + block_rows, :]

    # Horizontal pass (consumes the column halo).
    acc = jnp.zeros((block_rows, w_out), slab.dtype)
    for k, t in enumerate(taps):
        acc = acc + t * vert[:, k : k + w_out]

    o_ref[...] = acc


def resolve_block_rows(h: int, requested: int | None) -> int:
    """Pick the grid row-block: the largest divisor of ``h`` ≤ BLOCK_ROWS.

    Production tiles are 512 rows → 128-row blocks (4 programs).  Tests and
    SIFT's decimated octaves use smaller tiles; gcd keeps the grid exact
    without padding the output.
    """
    if requested is not None:
        if h % requested != 0:
            raise ValueError(f"H={h} not divisible by block_rows={requested}")
        return requested
    import math

    return math.gcd(h, BLOCK_ROWS)


@functools.partial(jax.jit, static_argnames=("sigma", "radius", "block_rows"))
def blur2d_pallas(
    x: jnp.ndarray,
    *,
    sigma: float,
    radius: int,
    block_rows: int | None = None,
) -> jnp.ndarray:
    """Separable Gaussian blur of an unpadded ``f32[H, W]`` tile via Pallas.

    Functionally identical to :func:`..kernels.ref.blur2d_ref`; pytest
    asserts allclose between the two.  ``H`` must be divisible by
    ``block_rows`` when given explicitly (tiles in this system are 512 rows;
    tests sweep other shapes via hypothesis).
    """
    h, w = x.shape
    block_rows = resolve_block_rows(h, block_rows)
    taps = gaussian_taps(sigma, radius)
    xp = pad_edge(x, radius)
    n_blocks = h // block_rows

    return pl.pallas_call(
        functools.partial(_blur_block_kernel, taps=taps, block_rows=block_rows),
        grid=(n_blocks,),
        # Full padded input visible to every program; output row-blocked.
        in_specs=[pl.BlockSpec(xp.shape, lambda i: (0, 0))],
        out_specs=pl.BlockSpec((block_rows, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w), x.dtype),
        interpret=True,
    )(xp)
