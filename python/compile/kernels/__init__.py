"""L1 Pallas kernels + their pure-jnp oracles.

Modules:
  conv   — separable Gaussian blur (row-blocked Pallas kernel)
  harris — fused structure-tensor corner response (Harris / Shi-Tomasi)
  ref    — pure-jnp reference implementations (correctness oracles)
"""

from .conv import blur2d_pallas  # noqa: F401
from .harris import structure_response_pallas  # noqa: F401
