"""L1 Pallas kernel: fused structure-tensor corner response (Harris / Shi-Tomasi).

The structure-tensor pipeline — Sobel gradients, the three gradient products
Ixx/Iyy/Ixy, a Gaussian window over each, and the scalar corner response —
is DIFET's second hot primitive (it opens both corner detectors and the
ORB/BRIEF keypoint rankings).  A naive composition materializes five
intermediate planes in HBM; this kernel fuses the entire chain so each
input element is read once and only the response plane is written back.

TPU mapping (§Hardware-Adaptation in DESIGN.md)
-----------------------------------------------
* Grid: 1-D over ``(BLOCK_ROWS, W)`` output slabs, like ``conv.py``.
* Per-program working set at BLOCK_ROWS=128, W=512, halo=4: the input slab
  (136×520), two gradient planes (134×518) and three product planes — about
  1.9 MiB f32, well inside VMEM; nothing round-trips through HBM.
* All arithmetic is element-wise / shifted-slice VPU work; the unrolled
  7-tap separable window is 14 fused multiply-adds per product plane.

Fusion is the optimization the paper gets implicitly from OpenCV's
``cornerHarris`` C++ loop nest; here it is explicit and benchmarked against
the unfused composition in ``cargo bench --bench ablations`` (L2-side) and
``python/tests/test_kernels.py`` checks numerics against the unfused
pure-jnp oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import (
    HARRIS_K,
    STRUCTURE_HALO,
    WINDOW_RADIUS,
    gaussian_taps,
    pad_edge,
)

BLOCK_ROWS = 128


def _structure_block_kernel(
    xp_ref,
    o_ref,
    *,
    mode: str,
    k: float,
    taps: tuple[float, ...],
    block_rows: int,
):
    """One grid step: fused response for ``block_rows`` output rows."""
    i = pl.program_id(0)
    halo = STRUCTURE_HALO  # 1 (Sobel) + WINDOW_RADIUS
    radius = WINDOW_RADIUS
    w_pad = xp_ref.shape[1]
    w_out = w_pad - 2 * halo

    # Slab covering the output rows plus the full stencil halo.
    slab = pl.load(
        xp_ref, (pl.dslice(i * block_rows, block_rows + 2 * halo), slice(None))
    )

    # --- Sobel gradients (valid: loses a 1-pixel ring) -------------------
    gh = block_rows + 2 * radius  # gradient plane height
    gw = w_pad - 2  # gradient plane width

    def sl(dr: int, dc: int) -> jnp.ndarray:
        return slab[1 + dr : 1 + dr + gh, 1 + dc : 1 + dc + gw]

    ix = (
        -sl(-1, -1) + sl(-1, 1)
        - 2.0 * sl(0, -1) + 2.0 * sl(0, 1)
        - sl(1, -1) + sl(1, 1)
    ) * 0.125
    iy = (
        -sl(-1, -1) - 2.0 * sl(-1, 0) - sl(-1, 1)
        + sl(1, -1) + 2.0 * sl(1, 0) + sl(1, 1)
    ) * 0.125

    # --- Gradient products, windowed in-register --------------------------
    def window(p: jnp.ndarray) -> jnp.ndarray:
        vert = jnp.zeros((block_rows, gw), p.dtype)
        for t_idx, t in enumerate(taps):
            vert = vert + t * p[t_idx : t_idx + block_rows, :]
        acc = jnp.zeros((block_rows, w_out), p.dtype)
        for t_idx, t in enumerate(taps):
            acc = acc + t * vert[:, t_idx : t_idx + w_out]
        return acc

    ixx = window(ix * ix)
    iyy = window(iy * iy)
    ixy = window(ix * iy)

    # --- Scalar response ---------------------------------------------------
    if mode == "harris":
        det = ixx * iyy - ixy * ixy
        tr = ixx + iyy
        resp = det - k * tr * tr
    else:  # shi_tomasi: min eigenvalue
        half_tr = 0.5 * (ixx + iyy)
        half_diff = 0.5 * (ixx - iyy)
        resp = half_tr - jnp.sqrt(half_diff * half_diff + ixy * ixy)

    o_ref[...] = resp


@functools.partial(
    jax.jit, static_argnames=("mode", "k", "window_sigma", "block_rows")
)
def structure_response_pallas(
    x: jnp.ndarray,
    *,
    mode: str = "harris",
    k: float = HARRIS_K,
    window_sigma: float = 1.5,
    block_rows: int | None = None,
) -> jnp.ndarray:
    """Fused Harris / Shi-Tomasi response of an unpadded ``f32[H, W]`` tile.

    Functional twin of :func:`..kernels.ref.structure_response_ref`.
    ``H`` must be divisible by ``block_rows`` when given explicitly.
    """
    if mode not in ("harris", "shi_tomasi"):
        raise ValueError(f"unknown structure response mode: {mode!r}")
    from .conv import resolve_block_rows

    h, w = x.shape
    block_rows = resolve_block_rows(h, block_rows)
    taps = gaussian_taps(window_sigma, WINDOW_RADIUS)
    xp = pad_edge(x, STRUCTURE_HALO)
    n_blocks = h // block_rows

    return pl.pallas_call(
        functools.partial(
            _structure_block_kernel,
            mode=mode,
            k=k,
            taps=taps,
            block_rows=block_rows,
        ),
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec(xp.shape, lambda i: (0, 0))],
        out_specs=pl.BlockSpec((block_rows, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w), x.dtype),
        interpret=True,
    )(xp)
