"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness signal).

Every Pallas kernel in this package has an exact functional twin here,
implemented with plain jax.numpy shift-and-accumulate stencils.  pytest
(``python/tests/test_kernels.py``) asserts ``allclose`` between the two over
hypothesis-generated shapes and contents; this is the core L1 correctness
gate demanded by the build process.

Conventions
-----------
* Images are ``f32[H, W]`` single-band (grayscale) tiles.
* "Padded" arrays carry an edge-replicated halo of ``halo`` pixels on every
  side, produced by :func:`pad_edge`.  Kernels consume padded inputs and emit
  valid (unpadded) outputs, so no boundary conditionals appear in the hot
  loop — the same trick the TPU kernel uses to keep the VPU lanes uniform.
"""

from __future__ import annotations

import math

import jax.numpy as jnp

# Radius of the Gaussian window used by the structure-tensor (Harris /
# Shi-Tomasi) kernels.  Sobel adds one more ring, hence STRUCTURE_HALO = 4.
WINDOW_RADIUS = 3
STRUCTURE_HALO = WINDOW_RADIUS + 1

# Harris corner response constant k (the classic 0.04..0.06 range; OpenCV's
# default examples use 0.04, which the paper's mapper inherits).
HARRIS_K = 0.04


def gaussian_taps(sigma: float, radius: int) -> tuple[float, ...]:
    """Normalized 1-D Gaussian taps with the given radius (static Python floats).

    Taps are baked into the kernels as compile-time constants so the lowered
    HLO contains immediate multiplies rather than a weights operand.
    """
    if radius < 0:
        raise ValueError(f"radius must be >= 0, got {radius}")
    if sigma <= 0.0:
        raise ValueError(f"sigma must be > 0, got {sigma}")
    raw = [math.exp(-0.5 * (i / sigma) ** 2) for i in range(-radius, radius + 1)]
    s = sum(raw)
    return tuple(t / s for t in raw)


def pad_edge(x: jnp.ndarray, halo: int) -> jnp.ndarray:
    """Edge-replicate pad a 2-D tile by ``halo`` pixels on every side."""
    return jnp.pad(x, ((halo, halo), (halo, halo)), mode="edge")


def blur2d_ref(x: jnp.ndarray, sigma: float, radius: int) -> jnp.ndarray:
    """Separable Gaussian blur of an unpadded tile (reference).

    Pads internally with edge replication, then applies the vertical and
    horizontal passes by shift-and-accumulate.
    """
    taps = gaussian_taps(sigma, radius)
    xp = pad_edge(x, radius)
    return _blur_cols_valid(_blur_rows_valid(xp, taps), taps)


def _blur_rows_valid(xp: jnp.ndarray, taps: tuple[float, ...]) -> jnp.ndarray:
    """Vertical (axis-0) tap accumulation; consumes the axis-0 halo."""
    radius = (len(taps) - 1) // 2
    out_h = xp.shape[0] - 2 * radius
    acc = jnp.zeros((out_h, xp.shape[1]), xp.dtype)
    for k, t in enumerate(taps):
        acc = acc + t * xp[k : k + out_h, :]
    return acc


def _blur_cols_valid(xp: jnp.ndarray, taps: tuple[float, ...]) -> jnp.ndarray:
    """Horizontal (axis-1) tap accumulation; consumes the axis-1 halo."""
    radius = (len(taps) - 1) // 2
    out_w = xp.shape[1] - 2 * radius
    acc = jnp.zeros((xp.shape[0], out_w), xp.dtype)
    for k, t in enumerate(taps):
        acc = acc + t * xp[:, k : k + out_w]
    return acc


def sobel_valid(xp: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """3x3 Sobel gradients of a padded array; output loses a 1-pixel ring.

    Returns ``(Ix, Iy)`` with shape ``(H-2, W-2)`` for input ``(H, W)``.
    """
    h, w = xp.shape
    oh, ow = h - 2, w - 2

    def sl(dr: int, dc: int) -> jnp.ndarray:
        return xp[1 + dr : 1 + dr + oh, 1 + dc : 1 + dc + ow]

    # Sobel x: [[-1,0,1],[-2,0,2],[-1,0,1]] / 8 ; y is its transpose.
    ix = (
        -sl(-1, -1) + sl(-1, 1)
        - 2.0 * sl(0, -1) + 2.0 * sl(0, 1)
        - sl(1, -1) + sl(1, 1)
    ) * 0.125
    iy = (
        -sl(-1, -1) - 2.0 * sl(-1, 0) - sl(-1, 1)
        + sl(1, -1) + 2.0 * sl(1, 0) + sl(1, 1)
    ) * 0.125
    return ix, iy


def structure_response_ref(
    xp: jnp.ndarray, mode: str, k: float = HARRIS_K, window_sigma: float = 1.5
) -> jnp.ndarray:
    """Reference structure-tensor corner response.

    ``xp`` must be padded by :data:`STRUCTURE_HALO`.  Output has the original
    (unpadded) shape.  ``mode`` is ``"harris"`` (det - k*tr^2) or
    ``"shi_tomasi"`` (min eigenvalue).
    """
    if mode not in ("harris", "shi_tomasi"):
        raise ValueError(f"unknown structure response mode: {mode!r}")
    taps = gaussian_taps(window_sigma, WINDOW_RADIUS)
    ix, iy = sobel_valid(xp)  # still padded by WINDOW_RADIUS
    ixx = _window_valid(ix * ix, taps)
    iyy = _window_valid(iy * iy, taps)
    ixy = _window_valid(ix * iy, taps)
    return structure_response_from_tensor(ixx, iyy, ixy, mode, k)


def structure_response_from_tensor(
    ixx: jnp.ndarray, iyy: jnp.ndarray, ixy: jnp.ndarray, mode: str, k: float = HARRIS_K
) -> jnp.ndarray:
    """Corner response from smoothed structure-tensor components."""
    if mode == "harris":
        det = ixx * iyy - ixy * ixy
        tr = ixx + iyy
        return det - k * tr * tr
    # Shi-Tomasi: smaller eigenvalue of [[ixx, ixy], [ixy, iyy]].
    half_tr = 0.5 * (ixx + iyy)
    half_diff = 0.5 * (ixx - iyy)
    return half_tr - jnp.sqrt(half_diff * half_diff + ixy * ixy)


def _window_valid(x: jnp.ndarray, taps: tuple[float, ...]) -> jnp.ndarray:
    """Separable window sum consuming the halo in both axes."""
    return _blur_cols_valid(_blur_rows_valid(x, taps), taps)
