//! Image matching/registration — the application the paper's intro
//! motivates (image matching, Wang et al. 2012; stitching of LandSat
//! mosaics, Sayar et al. 2013).
//!
//! Two "acquisitions" of the same area are simulated by cropping one
//! synthetic scene at two offsets; ORB features are extracted through the
//! full DIFET stack, matched with Hamming + ratio test, and the planted
//! translation is recovered by RANSAC.
//!
//! ```bash
//! cargo run --release --example image_matching
//! ```

use difet::config::SceneConfig;
use difet::coordinator::driver::{NativeExecutor, TileExecutor};
use difet::features::matching::{match_descriptors, ransac_translation};
use difet::imagery::{Rgba8Image, SceneGenerator};
use difet::runtime::{artifacts_available, Engine};
use difet::TILE;

/// Crop a TILE×TILE window at (row0, col0).
fn crop(img: &Rgba8Image, row0: usize, col0: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(TILE * TILE * 4);
    for r in 0..TILE {
        for c in 0..TILE {
            let px = img.get(row0 + r, col0 + c);
            out.extend_from_slice(&[px[0] as f32, px[1] as f32, px[2] as f32, px[3] as f32]);
        }
    }
    out
}

fn main() -> difet::Result<()> {
    // One big scene, two overlapping acquisitions offset by (40, -64).
    let mut cfg = SceneConfig::default();
    cfg.width = 900;
    cfg.height = 900;
    let scene = SceneGenerator::new(cfg).scene(0);
    let (dr_true, dc_true) = (40i32, -64i32);
    let a = crop(&scene.image, 100, 150);
    let b = crop(
        &scene.image,
        (100 + dr_true) as usize,
        (150 + dc_true) as usize,
    );

    // Extract ORB through the engine (PJRT if built, else native).
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let engine: Box<dyn TileExecutor> = if artifacts_available(&dir) {
        Box::new(Engine::load_subset(&dir, Some(&["orb"]))?)
    } else {
        Box::new(NativeExecutor)
    };
    let full = [0, TILE as i32, 0, TILE as i32];
    let fa = engine.run_tile("orb", &a, full)?;
    let fb = engine.run_tile("orb", &b, full)?;
    println!(
        "acquisition A: {} ORB keypoints; B: {} ({} executor)",
        fa.keypoints.len(),
        fb.keypoints.len(),
        engine.label()
    );

    // Match + register.
    let matches = match_descriptors(&fa.descriptors, &fb.descriptors, 0.85);
    println!("ratio-test matches: {}", matches.len());
    let t = ransac_translation(&fa.keypoints, &fb.keypoints, &matches, 3.0, 256, 7)
        .expect("no consensus translation");
    // B was cropped (dr, dc) further along, so B's keypoints sit at
    // A-coordinates minus the offset.
    println!(
        "recovered translation: ({:+.1}, {:+.1}) px with {} inliers (truth ({:+}, {:+}))",
        t.d_row, t.d_col, t.inliers, -dr_true, -dc_true
    );
    assert!(
        (t.d_row + dr_true as f32).abs() <= 2.0 && (t.d_col + dc_true as f32).abs() <= 2.0,
        "registration failed"
    );
    println!("registration OK");
    Ok(())
}
