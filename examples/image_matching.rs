//! Image matching/registration — the application the paper's intro
//! motivates (image matching, Wang et al. 2012; stitching of LandSat
//! mosaics, Sayar et al. 2013) — run as the full two-stage distributed
//! pipeline: overlapping acquisitions are bundled into DFS, a fused
//! extraction job keeps ORB descriptors through the shuffle, and the
//! registration job matches every scene pair reduce-side through the
//! Scheduler (locality, retries, speculation).  The recovered
//! translations are checked against the planted acquisition offsets and
//! against the sequential matching baseline, which the distributed job
//! must reproduce exactly.
//!
//! ```bash
//! cargo run --release --example image_matching
//! ```

use difet::config::Config;
use difet::pipeline::report::render_registration_table;
use difet::pipeline::{register_pairs_sequential, run_registration, RegistrationRequest};

fn main() -> difet::Result<()> {
    // A small 2-node cluster and three overlapping 900²-px acquisitions.
    let mut cfg = Config::new();
    cfg.scene.width = 900;
    cfg.scene.height = 900;
    cfg.cluster.nodes = 2;
    cfg.cluster.slots_per_node = 2;
    cfg.cluster.job_startup = 1.0;
    cfg.storage.block_size = 2 << 20;
    cfg.artifacts_dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));

    let req = RegistrationRequest {
        num_scenes: 3,
        max_offset: 96,
        ..Default::default()
    };
    let out = run_registration(&cfg, &req)?;
    println!(
        "extracted {} scenes ({} keypoints retained), registering {} pairs on {} nodes\n",
        out.extraction.image_count,
        out.extraction.images.iter().map(|i| i.keypoints.len()).sum::<usize>(),
        out.report.pair_count,
        out.report.nodes,
    );
    print!("{}", render_registration_table(&out.report));

    // Every pair overlaps (offsets ≤ 96 px on 900 px frames): all must
    // register, each within 2 px of the planted offset difference.
    for p in &out.report.pairs {
        let t = p
            .translation
            .as_ref()
            .unwrap_or_else(|| panic!("pair {}→{} failed to register", p.image_a, p.image_b));
        let (er, ec) = out.expected_translation(p.image_a, p.image_b);
        assert!(
            (t.d_row - er).abs() <= 2.0 && (t.d_col - ec).abs() <= 2.0,
            "pair {}→{}: recovered ({:+.1}, {:+.1}), planted ({er:+.1}, {ec:+.1})",
            p.image_a,
            p.image_b,
            t.d_row,
            t.d_col,
        );
    }

    // The distributed job must agree with the sequential baseline bit
    // for bit (same matches, same translations).
    let baseline = register_pairs_sequential(&out.extraction.images, &req.spec)?;
    assert_eq!(out.report.pairs, baseline, "distributed != sequential baseline");

    println!("\nregistration OK: all pairs within 2 px of planted offsets, baseline exact");
    Ok(())
}
