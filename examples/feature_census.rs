//! Table 2 regenerator: number of features per algorithm at N=3 and N=20.
//!
//! ```bash
//! cargo run --release --example feature_census -- --scenes 3,20
//! ```
//!
//! Absolute counts scale with scene area (default scenes are 1792² vs the
//! paper's ~7700²); what must reproduce is the *shape*: FAST ≫ Harris >
//! SIFT > SURF ≫ BRIEF, Shi-Tomasi pinned at 400·N and ORB at 500·N by
//! their OpenCV per-image caps.

use difet::config::Config;
use difet::pipeline::report::{ColumnKey, TableBuilder};
use difet::pipeline::{run_extraction, ExtractRequest};
use difet::util::args::{FlagSpec, ParsedArgs};

fn main() -> difet::Result<()> {
    let specs = vec![
        FlagSpec { name: "scenes", takes_value: true, help: "comma list of N (default 3,20)" },
        FlagSpec { name: "scene-size", takes_value: true, help: "scene edge px (default 1792)" },
        FlagSpec { name: "native", takes_value: false, help: "force pure-Rust executor" },
        FlagSpec { name: "fused", takes_value: false, help: "one fused pass for all algorithms" },
    ];
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let p = ParsedArgs::parse(&argv, &specs, false).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2)
    });

    let mut cfg = Config::new();
    if let Some(px) = p.get("scene-size") {
        let px: usize = px.parse().expect("--scene-size");
        cfg.scene.width = px;
        cfg.scene.height = px;
    }
    cfg.cluster.nodes = 4;

    let ns: Vec<usize> = p
        .get_or("scenes", "3,20")
        .split(',')
        .map(|s| s.trim().parse().expect("--scenes"))
        .collect();

    let mut tb = TableBuilder::new();
    for &n in &ns {
        eprintln!("[census] N={n}…");
        let req = ExtractRequest {
            num_scenes: n,
            write_output: false,
            force_native: p.has("native"),
            fused: p.has("fused"),
            ..Default::default()
        };
        let rep = run_extraction(&cfg, &req)?;
        for j in &rep.jobs {
            tb.add(ColumnKey { nodes: 4, scenes: n }, j);
        }
    }

    println!("{}", tb.render_table2());
    println!("Paper's Table 2 for reference (7681x7831 scenes):");
    for (alg, n3, n20) in [
        ("Harris Corner Detection", 140_702u64, 943_159u64),
        ("Shi-Tomasi", 1_200, 8_000),
        ("SIFT", 123_960, 832_604),
        ("SURF", 58_692, 398_289),
        ("FAST", 707_264, 4_762_222),
        ("BRIEF", 3_478, 23_547),
        ("ORB", 1_500, 10_000),
    ] {
        println!(
            "  {alg:<26}{:>12}{:>14}",
            difet::util::fmt::with_commas(n3),
            difet::util::fmt::with_commas(n20)
        );
    }
    println!(
        "\nShape checks: Shi-Tomasi = 400·N and ORB = 500·N exactly (OpenCV caps);\n\
         FAST dominates; BRIEF sparse.  See EXPERIMENTS.md §Table 2."
    );
    Ok(())
}
