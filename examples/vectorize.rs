//! Vectorization — the full nine-stage DAG (ingest → extract ⇒
//! census-merge / register ⇒ register-merge → align → composite →
//! vectorize ⇒ label-merge) on the simulated cluster: overlapping
//! acquisitions are stitched into one mosaic, the mosaic is thresholded
//! into a foreground mask, the mask is labeled as band-shaped work
//! units on the coordinator (the fourth `WorkItem` shape), and every
//! object becomes a simplified polygon with exact attributes.  The run
//! checks itself: the distributed label raster and the traced polygons
//! must equal the sequential `label_sequential` baseline bit for bit.
//!
//! ```bash
//! cargo run --release --example vectorize
//! ```

use difet::config::Config;
use difet::pipeline::report::render_vector_table;
use difet::pipeline::{run_vectorize, RegistrationRequest, StitchRequest, VectorizeRequest};

fn main() -> difet::Result<()> {
    // A small 2-node cluster and three overlapping 480²-px acquisitions.
    let mut cfg = Config::new();
    cfg.scene.width = 480;
    cfg.scene.height = 480;
    cfg.cluster.nodes = 2;
    cfg.cluster.slots_per_node = 2;
    cfg.cluster.job_startup = 1.0;
    cfg.storage.block_size = 1 << 20;
    cfg.artifacts_dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));

    let req = VectorizeRequest {
        stitch: StitchRequest {
            reg: RegistrationRequest {
                num_scenes: 3,
                max_offset: 64,
                ..Default::default()
            },
            ..Default::default()
        },
        ..Default::default()
    };
    let out = run_vectorize(&cfg, &req)?;
    println!(
        "vectorized a {}×{} mosaic of {} scene(s): {} object(s), {} band tile(s), \
         max merge residual {}\n",
        out.stitch.mosaic.width,
        out.stitch.mosaic.height,
        out.stitch.scenes.len(),
        out.object_count(),
        out.vector.report.tile_count,
        out.max_merge_residual(),
    );
    print!("{}", render_vector_table(&out.vector.report, &out.vector.objects));

    // The synthetic scenes are piecewise-bright (settlements, roads) on
    // darker fields/water, so a mid-gray threshold must find objects.
    assert!(out.object_count() > 0, "no objects above the threshold");
    assert!(
        out.vector.report.tile_count >= 2,
        "mask should split into several band work units"
    );

    // The determinism contract, end to end: the distributed band-tile
    // labeling (and everything traced from it) equals the sequential
    // baseline bit for bit.
    let (labels, stats) = out.vector.labels_baseline();
    assert_eq!(out.vector.labels, labels, "distributed labels != sequential baseline");
    assert_eq!(out.vector.stats, stats, "object stats != sequential baseline");
    assert_eq!(
        out.vector.objects,
        out.vector.objects_baseline(),
        "polygons != sequential baseline"
    );

    // The GeoJSON document round-trips through the in-crate parser.
    let doc = out.vector.geojson();
    let parsed = difet::util::json::parse(&doc.to_string()).expect("geojson must parse");
    assert_eq!(parsed, doc);

    println!(
        "\nvectorize OK: {} object(s), distributed labeling bit-identical to the \
         sequential baseline",
        out.object_count()
    );
    Ok(())
}
