//! Mosaicking — the full stitch pipeline (ingest → register → align →
//! composite) on the simulated cluster: overlapping acquisitions are
//! registered pairwise, the pair graph is solved for per-scene absolute
//! positions, and the canvas is composited as tile-shaped work units on
//! the coordinator with distance-feathered blending.  The run checks
//! itself: solved positions must land within 1 px of the planted
//! acquisition offsets, and the distributed composite must equal the
//! sequential baseline byte for byte.
//!
//! ```bash
//! cargo run --release --example mosaic
//! ```

use difet::config::Config;
use difet::mosaic::BlendMode;
use difet::pipeline::report::render_mosaic_table;
use difet::pipeline::{run_stitch, RegistrationRequest, StitchRequest};

fn main() -> difet::Result<()> {
    // A small 2-node cluster and four overlapping 700²-px acquisitions.
    let mut cfg = Config::new();
    cfg.scene.width = 700;
    cfg.scene.height = 700;
    cfg.cluster.nodes = 2;
    cfg.cluster.slots_per_node = 2;
    cfg.cluster.job_startup = 1.0;
    cfg.storage.block_size = 2 << 20;
    cfg.artifacts_dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));

    let req = StitchRequest {
        reg: RegistrationRequest {
            num_scenes: 4,
            max_offset: 96,
            ..Default::default()
        },
        blend: BlendMode::Feather,
        ..Default::default()
    };
    let out = run_stitch(&cfg, &req)?;
    println!(
        "stitched {} scenes: {} pair(s) registered, canvas {}×{}, {} canvas tile(s)\n",
        out.scenes.len(),
        out.registration.report.registered_count(),
        out.report.canvas_width,
        out.report.canvas_height,
        out.report.tile_count,
    );
    print!("{}", render_mosaic_table(&out.alignment, &out.report));

    // Every acquisition is a crop of one master scene, so the solved
    // positions must recover the planted offsets to sub-pixel accuracy
    // (scene 0 anchors at (0, 0), like the offset table).
    let err = out.max_position_error(&out.registration.offsets);
    assert!(err <= 1.0, "max position error {err:.2} px exceeds 1 px");

    // One connected component (everything overlaps), zero seam error
    // (exact crops + exact alignment → identical pixels in overlaps).
    assert_eq!(out.alignment.components.len(), 1, "overlapping scenes must form one component");
    assert!(
        out.report.max_cycle_residual < 1.0,
        "cycle residual {:.2} px",
        out.report.max_cycle_residual
    );

    // The distributed canvas-tile composite must equal the sequential
    // whole-canvas baseline byte for byte.
    let baseline = out.composite_baseline(req.blend)?;
    assert_eq!(
        out.mosaic.data, baseline.data,
        "distributed mosaic != sequential composite"
    );

    println!(
        "\nmosaic OK: positions within {err:.2} px of planted offsets, \
         distributed composite bit-identical to the sequential baseline"
    );
    Ok(())
}
