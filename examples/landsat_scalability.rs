//! Table 1 regenerator: horizontal scalability of the seven extractors.
//!
//! Sweeps {sequential, 2-node MR, 4-node MR} × {N=3, N=20} like the
//! paper's Section 4 and prints the same table shape.  Scene size
//! defaults to 1792² (≈1/18 of the paper's 7681×7831 pixel count) so the
//! sweep finishes in minutes; pass `--paper-scale` for the full geometry
//! (budget ~1 h) or `--scene-size <px>` for anything between.
//!
//! ```bash
//! cargo run --release --example landsat_scalability -- --scenes 3,20
//! ```

use difet::config::Config;
use difet::pipeline::report::{ColumnKey, TableBuilder};
use difet::pipeline::{run_extraction, run_sequential, ExtractRequest};
use difet::util::args::{FlagSpec, ParsedArgs};

fn main() -> difet::Result<()> {
    let specs = vec![
        FlagSpec { name: "scenes", takes_value: true, help: "comma list of N (default 3,20)" },
        FlagSpec { name: "scene-size", takes_value: true, help: "scene edge px (default 1792)" },
        FlagSpec { name: "paper-scale", takes_value: false, help: "use 7681x7831 scenes" },
        FlagSpec { name: "algorithms", takes_value: true, help: "subset (default all)" },
        FlagSpec { name: "native", takes_value: false, help: "force pure-Rust executor" },
    ];
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let p = ParsedArgs::parse(&argv, &specs, false).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2)
    });

    let mut cfg = Config::new();
    if p.has("paper-scale") {
        cfg.scene = difet::config::SceneConfig::paper_scale();
    } else if let Some(px) = p.get("scene-size") {
        let px: usize = px.parse().expect("--scene-size");
        cfg.scene.width = px;
        cfg.scene.height = px;
    }
    let scene_px = cfg.scene.width * cfg.scene.height;
    let paper_px = 7681usize * 7831;
    println!(
        "scene {}x{} ({:.1}% of the paper's pixel count); costs modeled on the \
         paper's testbed (i7-950, SATA2, 1 GbE, Hadoop 1.x overheads)\n",
        cfg.scene.width,
        cfg.scene.height,
        100.0 * scene_px as f64 / paper_px as f64
    );

    let ns: Vec<usize> = p
        .get_or("scenes", "3,20")
        .split(',')
        .map(|s| s.trim().parse().expect("--scenes"))
        .collect();

    let mut req = ExtractRequest::default();
    if let Some(algs) = p.get_list("algorithms") {
        req.algorithms = algs;
    }
    req.write_output = true;
    req.force_native = p.has("native");

    let mut tb = TableBuilder::new();
    for &n in &ns {
        req.num_scenes = n;

        eprintln!("[sweep] sequential N={n}…");
        let seq = run_sequential(&cfg, &req)?;
        for j in &seq.jobs {
            tb.add(ColumnKey { nodes: 0, scenes: n }, j);
        }

        for nodes in [2usize, 4] {
            eprintln!("[sweep] {nodes}-node MapReduce N={n}…");
            let mut c = cfg.clone();
            c.cluster.nodes = nodes;
            let rep = run_extraction(&c, &req)?;
            for j in &rep.jobs {
                tb.add(ColumnKey { nodes, scenes: n }, j);
            }
        }
    }

    println!("{}", tb.render_table1());
    println!("Paper's Table 1 for reference (seconds, full-scale testbed):");
    println!("  Alg          seq N=3  seq N=20  2nd N=3  2nd N=20  4nd N=3  4nd N=20");
    for (alg, row) in [
        ("Harris", [68.0, 600.0, 44.0, 523.0, 24.0, 174.0]),
        ("Shi-Tomasi", [77.0, 441.0, 31.0, 256.0, 10.0, 85.0]),
        ("SIFT", [4140.0, 27981.0, 1309.0, 8818.0, 459.0, 2945.0]),
        ("SURF", [94.0, 546.0, 110.0, 793.0, 39.0, 260.0]),
        ("FAST", [14.0, 95.0, 21.0, 138.0, 6.0, 43.0]),
        ("BRIEF", [143.0, 846.0, 86.0, 511.0, 35.0, 316.0]),
        ("ORB", [30.0, 205.0, 26.0, 169.0, 9.0, 58.0]),
    ] {
        println!(
            "  {alg:<12}{:>8}{:>10}{:>9}{:>10}{:>9}{:>10}",
            row[0], row[1], row[2], row[3], row[4], row[5]
        );
    }
    Ok(())
}
