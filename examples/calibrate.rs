// One-off calibration probe (not part of the crate).
use difet::config::SceneConfig;
use difet::features::{conv, fast, gray::GrayImage, harris, surf};
use difet::imagery::SceneGenerator;

fn density(mask: &[bool]) -> f64 { mask.iter().filter(|&&m| m).count() as f64 / mask.len() as f64 }

fn main() {
    let mut cfg = SceneConfig::default();
    cfg.width = 1024; cfg.height = 1024;
    let scene = SceneGenerator::new(cfg).scene(0);
    let g = GrayImage::from_rgba(&scene.image);

    // Shi-Tomasi response distribution (BRIEF detector).
    let st = harris::response(&g, harris::Mode::ShiTomasi);
    let mut vals: Vec<f32> = st.data.clone(); vals.sort_by(|a,b| b.total_cmp(a));
    for q in [50usize, 200, 1000, 5000, 20000] {
        println!("shi-tomasi resp: top-{}th value = {:.5e}", q, vals[q]);
    }
    // FAST density vs t.
    for t in [0.02f32, 0.03, 0.04, 0.05, 0.06] {
        let (mask, _) = fast::maps(&g, t);
        println!("fast t={t}: corner density {:.4}%", 100.0*density(&mask));
    }
    // Harris density with rel threshold + NMS.
    let e = harris::extract(&g, (0,1024,0,1024), 1_000_000, harris::Mode::Harris);
    println!("harris count (rel 0.01): {} ({:.4}%)", e.count, 100.0*e.count as f64/(1024.0*1024.0));
    let _ = (conv::gaussian_taps(1.0,2), surf::hessian_det(&g, 1.2));
}
