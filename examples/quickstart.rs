//! Quickstart: extract Harris corners from one synthetic LandSat scene.
//!
//! ```bash
//! make artifacts          # once; otherwise the native fallback runs
//! cargo run --release --example quickstart
//! ```

use difet::config::Config;
use difet::pipeline::{run_sequential, ExtractRequest};

fn main() -> difet::Result<()> {
    // A small scene so the example finishes in seconds.
    let mut cfg = Config::new();
    cfg.scene.width = 1024;
    cfg.scene.height = 1024;

    let req = ExtractRequest {
        algorithms: vec!["harris".into()],
        num_scenes: 1,
        write_output: false,
        force_native: false,
    };

    let report = run_sequential(&cfg, &req)?;
    let job = report.job("harris").expect("harris job");
    let image = &job.images[0];

    println!(
        "scene 0 ({}x{}): {} Harris corners  [{} executor, {:.2}s compute]",
        cfg.scene.width,
        cfg.scene.height,
        image.count,
        report.executor,
        job.compute_seconds
    );
    println!("\nstrongest corners (scene coordinates):");
    for kp in image.keypoints.iter().take(5) {
        println!("  ({:>4}, {:>4})  response {:.3e}", kp.row, kp.col, kp.score);
    }
    Ok(())
}
