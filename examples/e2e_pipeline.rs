//! END-TO-END DRIVER (DESIGN.md §End-to-end validation).
//!
//! Exercises every layer of the stack on one real small workload:
//!
//!   1. generate a 20-scene synthetic LandSat corpus (imagery),
//!   2. bundle it into a HIB file under backpressure (hib + coordinator),
//!   3. write it into the replicated DFS (dfs),
//!   4. run all seven extraction jobs on 1-, 2- and 4-node simulated
//!      clusters through the PJRT-compiled Pallas/JAX artifacts
//!      (coordinator + runtime + L2 + L1),
//!   5. print Table 1 + Table 2 and the throughput summary recorded in
//!      EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```
//!
//! Runtime ≈ a few minutes at the default 1344² scenes (pass
//! `--scene-size 896` for a faster smoke run).

use difet::config::Config;
use difet::pipeline::report::{ColumnKey, TableBuilder};
use difet::pipeline::{run_extraction, run_sequential, ExtractRequest};
use difet::util::args::{FlagSpec, ParsedArgs};
use difet::util::fmt;

fn main() -> difet::Result<()> {
    let specs = vec![
        FlagSpec { name: "scene-size", takes_value: true, help: "scene edge px (default 1344)" },
        FlagSpec { name: "scenes", takes_value: true, help: "corpus size (default 20)" },
        FlagSpec { name: "native", takes_value: false, help: "force pure-Rust executor" },
    ];
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let p = ParsedArgs::parse(&argv, &specs, false).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2)
    });

    let mut cfg = Config::new();
    let px: usize = p.get_or("scene-size", "1344").parse().expect("--scene-size");
    cfg.scene.width = px;
    cfg.scene.height = px;
    let n: usize = p.get_or("scenes", "20").parse().expect("--scenes");

    let req = ExtractRequest {
        num_scenes: n,
        write_output: true,
        force_native: p.has("native"),
        ..Default::default()
    };

    println!("=== DIFET end-to-end driver ===");
    println!("corpus: {n} scenes of {px}x{px} RGBA ({} raw)\n", fmt::bytes((n * px * px * 4) as u64));

    let mut tb = TableBuilder::new();
    let total = std::time::Instant::now();

    // Sequential baseline (Table 1 column 1).
    eprintln!("[e2e] sequential baseline…");
    let seq = run_sequential(&cfg, &req)?;
    println!("--- one node, sequential ({} executor) ---", seq.executor);
    print!("{}", seq.render_table());
    for j in &seq.jobs {
        tb.add(ColumnKey { nodes: 0, scenes: n }, j);
    }

    // Cluster runs (Table 1 columns 2–3).
    for nodes in [2usize, 4] {
        eprintln!("[e2e] {nodes}-node cluster…");
        let mut c = cfg.clone();
        c.cluster.nodes = nodes;
        let rep = run_extraction(&c, &req)?;
        println!(
            "\n--- {nodes}-node MapReduce (ingest {:.1}s, bundle {}) ---",
            rep.corpus.ingest_seconds,
            fmt::bytes(rep.corpus.bundle_bytes)
        );
        print!("{}", rep.render_table());
        for j in &rep.jobs {
            tb.add(ColumnKey { nodes, scenes: n }, j);
        }

        if nodes == 4 {
            // Throughput headline: scenes/hour at 4 nodes, per algorithm.
            println!("\nthroughput @4 nodes:");
            for j in &rep.jobs {
                println!(
                    "  {:<12} {:>8.1} scenes/h (sim)   census {:>12}",
                    j.algorithm,
                    3600.0 * n as f64 / j.sim_seconds,
                    fmt::with_commas(j.total_count())
                );
            }
        }
    }

    println!("\n{}", tb.render_table1());
    println!("{}", tb.render_table2());

    println!("wall total: {}", fmt::duration(total.elapsed().as_secs_f64()));
    println!("\nRecorded in EXPERIMENTS.md §End-to-end.");
    Ok(())
}
